//! kernel-bench: naive-vs-blocked GEMM GFLOP/s across square and
//! conv-shaped problems, arena-on vs arena-off warm serve latency for
//! the im2col conv hot path, and the bf16-vs-f32 mixed-precision sweep
//! (GFLOP/s plus real packing-traffic counters against the perf model's
//! byte-traffic advantage) — the acceptance evidence for the blocked
//! packed-GEMM engine, the zero-allocation workspace arena, and the
//! reduced-precision execution path. Results serialize to
//! `BENCH_kernels.json` (see the `kernel-bench` CLI subcommand, the CI
//! smoke job, and the tier-1 regeneration test).

use std::collections::BTreeMap;
use std::path::Path;

use crate::bench::BenchConfig;
use crate::perfmodel::GcnModel;
use crate::runtime::interp::arena::WorkspaceArena;
use crate::runtime::interp::gemm;
use crate::runtime::interp::kernels as k;
use crate::runtime::interp::view::{Bf16Src, TensorView};
use crate::runtime::tensor::f32s_to_bf16_bytes;
use crate::types::{DType, Result};
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// One GEMM measurement: the naive triple loop vs the blocked engine
/// (serial) vs the blocked engine with the thread pool.
#[derive(Debug, Clone)]
pub struct GemmPoint {
    /// Shape label ("256x256x256", "conv 32x144x784", ...).
    pub name: String,
    /// Output rows.
    pub m: usize,
    /// Inner dimension.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// Naive triple-loop throughput.
    pub naive_gflops: f64,
    /// Blocked engine, one thread.
    pub blocked_gflops: f64,
    /// Blocked engine, shared thread pool.
    pub blocked_par_gflops: f64,
    /// blocked (serial) over naive.
    pub speedup: f64,
}

/// Arena-on vs arena-off warm latency of the im2col conv hot path, with
/// the allocation counters that prove the warm path never allocates.
#[derive(Debug, Clone)]
pub struct ArenaPoint {
    /// Problem label (the conv geometry).
    pub name: String,
    /// Mean warm latency with a persistent arena (µs).
    pub warm_arena_us: f64,
    /// Mean warm latency allocating fresh scratch every call (µs).
    pub warm_fresh_us: f64,
    /// Arena allocations during the timed warm phase (must be 0).
    pub warm_allocs: u64,
    /// Arena reuses during the timed warm phase.
    pub warm_reuses: u64,
}

impl ArenaPoint {
    /// fresh-allocation latency over arena latency.
    pub fn speedup(&self) -> f64 {
        if self.warm_arena_us > 0.0 {
            self.warm_fresh_us / self.warm_arena_us
        } else {
            0.0
        }
    }
}

/// One bf16-vs-f32 mixed-precision GEMM measurement: throughput of the
/// same problem with 2-byte vs 4-byte storage, and the pack-stage
/// byte-traffic counters that prove the bf16 path reads half the bytes.
#[derive(Debug, Clone)]
pub struct DtypePoint {
    /// Shape label.
    pub name: String,
    /// Output rows.
    pub m: usize,
    /// Inner dimension.
    pub k: usize,
    /// Output columns.
    pub n: usize,
    /// Blocked engine over f32 storage.
    pub f32_gflops: f64,
    /// Blocked engine over bf16 storage (decode-at-pack, f32 accumulate).
    pub bf16_gflops: f64,
    /// Real pack-stage source bytes read on the f32 run (arena counter).
    pub f32_pack_bytes: u64,
    /// Real pack-stage source bytes read on the bf16 run.
    pub bf16_pack_bytes: u64,
    /// Modeled f32-over-bf16 byte-traffic advantage
    /// ([`GcnModel::gemm_pack_traffic_bytes`]) — 2.0 for 2-byte storage.
    pub modeled_advantage: f64,
}

impl DtypePoint {
    /// Measured f32-over-bf16 packing-traffic advantage (≥ 1.5 required
    /// by the CI acceptance; exactly 2.0 when both operands are bf16).
    pub fn pack_traffic_advantage(&self) -> f64 {
        if self.bf16_pack_bytes > 0 {
            self.f32_pack_bytes as f64 / self.bf16_pack_bytes as f64
        } else {
            0.0
        }
    }
}

/// The 1×1-conv NHWC-vs-NCHW layout measurement: warm im2col-GEMM
/// latency for the same problem in both layouts, plus the real
/// pack-stage byte counters. A 1×1 NHWC activation is already the
/// (Ho·Wo, C) GEMM operand — the unfold is a straight channel-run copy
/// and the filter enters through the transposed-B packing mode, so the
/// channels-last path must not pay more pack traffic than NCHW.
#[derive(Debug, Clone)]
pub struct LayoutPoint {
    /// Problem label (the conv geometry).
    pub name: String,
    /// Mean warm NCHW im2col latency (µs).
    pub nchw_us: f64,
    /// Mean warm NHWC im2col latency (µs).
    pub nhwc_us: f64,
    /// Pack-stage source bytes per NCHW run (arena counter).
    pub nchw_pack_bytes: u64,
    /// Pack-stage source bytes per NHWC run (arena counter).
    pub nhwc_pack_bytes: u64,
}

impl LayoutPoint {
    /// NCHW-over-NHWC packing-traffic ratio (≥ 1.0 means channels-last
    /// pays no extra pack bytes on the 1×1 hot path).
    pub fn pack_traffic_ratio(&self) -> f64 {
        if self.nhwc_pack_bytes > 0 {
            self.nchw_pack_bytes as f64 / self.nhwc_pack_bytes as f64
        } else {
            0.0
        }
    }
}

/// Grouped-direct vs the dedicated depthwise kernel on a g == c
/// problem — the evidence that promoting depthwise out of the grouped
/// fallback pays.
#[derive(Debug, Clone)]
pub struct DepthwisePoint {
    /// Problem label (the conv geometry).
    pub name: String,
    /// Grouped-direct fallback (the old serving path), NCHW (µs).
    pub grouped_direct_us: f64,
    /// Dedicated depthwise kernel, NCHW (µs).
    pub depthwise_nchw_us: f64,
    /// Dedicated depthwise kernel, channels-last (µs).
    pub depthwise_nhwc_us: f64,
}

impl DepthwisePoint {
    /// Grouped-direct latency over the best dedicated-kernel latency
    /// (the CI acceptance requires ≥ 1.0: the solver must not lose to
    /// the fallback it replaced).
    pub fn speedup(&self) -> f64 {
        let best = self.depthwise_nchw_us.min(self.depthwise_nhwc_us);
        if best > 0.0 {
            self.grouped_direct_us / best
        } else {
            0.0
        }
    }
}

/// The full kernel-bench result set.
#[derive(Debug, Clone)]
pub struct KernelBench {
    /// GEMM sweep points.
    pub gemm: Vec<GemmPoint>,
    /// The arena serve-latency measurement.
    pub arena: ArenaPoint,
    /// bf16-vs-f32 mixed-precision sweep points.
    pub bf16: Vec<DtypePoint>,
    /// The 1×1-conv NHWC-vs-NCHW layout measurement.
    pub layout: LayoutPoint,
    /// The depthwise-vs-grouped-direct measurement.
    pub depthwise: DepthwisePoint,
}

/// The swept GEMM shapes: square problems (the classic blocking
/// benchmark, 256³ is the acceptance shape) and conv-shaped panels
/// (K × C·R·S × Ho·Wo as the im2col GEMM sees them).
pub fn gemm_shapes() -> Vec<(String, usize, usize, usize)> {
    vec![
        ("64x64x64".into(), 64, 64, 64),
        ("128x128x128".into(), 128, 128, 128),
        ("256x256x256".into(), 256, 256, 256),
        ("conv 32x144x784".into(), 32, 144, 784),
        ("conv 64x576x196".into(), 64, 576, 196),
    ]
}

fn gflops(m: usize, k: usize, n: usize, us: f64) -> f64 {
    if us <= 0.0 {
        return 0.0;
    }
    2.0 * (m * k * n) as f64 / (us * 1e-6) / 1e9
}

/// Run the naive-vs-blocked GEMM sweep.
pub fn run_gemm_sweep(cfg: &BenchConfig) -> Vec<GemmPoint> {
    let mut rng = SplitMix64::new(0xB35C);
    let mut points = Vec::new();
    for (name, m, k, n) in gemm_shapes() {
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; k * n];
        rng.fill_normal_f32(&mut a);
        rng.fill_normal_f32(&mut b);
        let arena = WorkspaceArena::new();
        let mut out = vec![0f32; m * n];

        let naive = crate::bench::time_fn(cfg, || {
            out = gemm::naive_matmul(&a, &b, m, k, n);
        })
        .median();
        let blocked = crate::bench::time_fn(cfg, || {
            gemm::gemm_into(&mut out, &a, &b, m, k, n, false, false,
                            gemm::DEFAULT_TILE, 1, &arena);
        })
        .median();
        let blocked_par = crate::bench::time_fn(cfg, || {
            gemm::gemm_into(&mut out, &a, &b, m, k, n, false, false,
                            gemm::DEFAULT_TILE, 0, &arena);
        })
        .median();

        let naive_gflops = gflops(m, k, n, naive);
        let blocked_gflops = gflops(m, k, n, blocked);
        points.push(GemmPoint {
            name,
            m,
            k,
            n,
            naive_gflops,
            blocked_gflops,
            blocked_par_gflops: gflops(m, k, n, blocked_par),
            speedup: if naive_gflops > 0.0 {
                blocked_gflops / naive_gflops
            } else {
                0.0
            },
        });
    }
    points
}

/// Measure the warm im2col conv path: persistent arena (the serve
/// configuration — scratch reused, zero allocations) vs a fresh arena
/// per call (the pre-arena behavior).
pub fn run_arena_bench(cfg: &BenchConfig) -> ArenaPoint {
    let g = k::ConvGeom::dense(4, 16, 28, 28, 32, 3, 3, 1, 1);
    let mut rng = SplitMix64::new(0xA43A);
    let mut x = vec![0f32; g.n * g.c * g.h * g.w];
    let mut w = vec![0f32; g.k * g.c * g.r * g.s];
    rng.fill_normal_f32(&mut x);
    rng.fill_normal_f32(&mut w);

    let arena = WorkspaceArena::new();
    // one warmup populates the pool, then snapshot the counters: the
    // timed phase must not allocate
    let _ = k::conv2d_fwd_im2col_with(&x, &w, &g, gemm::DEFAULT_TILE,
                                      &arena);
    let before = arena.stats();
    let warm_arena_us = crate::bench::time_fn(cfg, || {
        let _ = k::conv2d_fwd_im2col_with(&x, &w, &g, gemm::DEFAULT_TILE,
                                          &arena);
    })
    .median();
    let after = arena.stats();

    let warm_fresh_us = crate::bench::time_fn(cfg, || {
        let _ = k::conv2d_fwd_im2col_with(&x, &w, &g, gemm::DEFAULT_TILE,
                                          &WorkspaceArena::new());
    })
    .median();

    ArenaPoint {
        name: format!("conv_fwd gemm n{}c{}h{}w{}k{}r{}s{}",
                      g.n, g.c, g.h, g.w, g.k, g.r, g.s),
        warm_arena_us,
        warm_fresh_us,
        warm_allocs: after.allocs - before.allocs,
        warm_reuses: after.reuses - before.reuses,
    }
}

/// The bf16-vs-f32 swept shapes: one square and one conv-shaped panel
/// (both above the engine's packing threshold, so the dtype-aware pack
/// stage — not the small-problem loop — is what gets measured).
pub fn dtype_shapes() -> Vec<(String, usize, usize, usize)> {
    vec![
        ("128x128x128".into(), 128, 128, 128),
        ("conv 64x576x196".into(), 64, 576, 196),
    ]
}

/// Run the bf16-vs-f32 mixed-precision GEMM sweep: same values, f32 vs
/// bf16 storage encodings, each run on a private arena so the
/// packing-traffic counters isolate one dtype's byte reads.
pub fn run_dtype_sweep(cfg: &BenchConfig) -> Vec<DtypePoint> {
    let mut rng = SplitMix64::new(0xBF16);
    let mut points = Vec::new();
    for (name, m, k, n) in dtype_shapes() {
        let mut af = vec![0f32; m * k];
        let mut bf = vec![0f32; k * n];
        rng.fill_normal_f32(&mut af);
        rng.fill_normal_f32(&mut bf);
        let (ab, bb) = (f32s_to_bf16_bytes(&af), f32s_to_bf16_bytes(&bf));
        let mut out = vec![0f32; m * n];

        let f32_arena = WorkspaceArena::new();
        let f32_us = crate::bench::time_fn(cfg, || {
            gemm::gemm_into(&mut out, &af, &bf, m, k, n, false, false,
                            gemm::DEFAULT_TILE, 1, &f32_arena);
        })
        .median();
        let f32_runs = (cfg.warmup_iters + cfg.timed_iters) as u64;
        let f32_pack_bytes =
            f32_arena.stats().pack_traffic_bytes / f32_runs.max(1);

        let bf16_arena = WorkspaceArena::new();
        let bf16_us = crate::bench::time_fn(cfg, || {
            gemm::gemm_into_src(&mut out, Bf16Src(&ab), Bf16Src(&bb), m, k,
                                n, false, false, gemm::DEFAULT_TILE, 1,
                                &bf16_arena);
        })
        .median();
        let bf16_runs = (cfg.warmup_iters + cfg.timed_iters) as u64;
        let bf16_pack_bytes =
            bf16_arena.stats().pack_traffic_bytes / bf16_runs.max(1);

        let modeled_f32 =
            GcnModel::gemm_pack_traffic_bytes(m, k, n, DType::F32) as f64;
        let modeled_bf16 =
            GcnModel::gemm_pack_traffic_bytes(m, k, n, DType::Bf16) as f64;
        points.push(DtypePoint {
            name,
            m,
            k,
            n,
            f32_gflops: gflops(m, k, n, f32_us),
            bf16_gflops: gflops(m, k, n, bf16_us),
            f32_pack_bytes,
            bf16_pack_bytes,
            modeled_advantage: modeled_f32 / modeled_bf16,
        });
    }
    points
}

fn f32_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Measure the warm 1×1 im2col conv in both layouts, each on a private
/// arena so the pack-traffic counters isolate one layout's byte reads.
pub fn run_layout_bench(cfg: &BenchConfig) -> LayoutPoint {
    let g = k::ConvGeom::dense(4, 16, 28, 28, 32, 1, 1, 1, 0);
    let mut rng = SplitMix64::new(0x17A0);
    let mut x = vec![0f32; g.n * g.c * g.h * g.w];
    let mut w = vec![0f32; g.k * g.c * g.r * g.s];
    rng.fill_normal_f32(&mut x);
    rng.fill_normal_f32(&mut w);

    let nchw_arena = WorkspaceArena::new();
    let nchw_us = crate::bench::time_fn(cfg, || {
        let _ = k::conv2d_fwd_im2col_with(&x, &w, &g, gemm::DEFAULT_TILE,
                                          &nchw_arena);
    })
    .median();
    let runs = (cfg.warmup_iters + cfg.timed_iters) as u64;
    let nchw_pack_bytes =
        nchw_arena.stats().pack_traffic_bytes / runs.max(1);

    // the same values, channels-last: x (N,H,W,C), w (K,R,S,C)
    let mut xh = vec![0f32; x.len()];
    k::nchw_to_nhwc_image(&x, g.n, g.c, g.h, g.w, &mut xh);
    let mut wh = vec![0f32; w.len()];
    k::kcrs_to_krsc(&w, g.k, g.c, g.r, g.s, &mut wh);
    let (xb, wb) = (f32_bytes(&xh), f32_bytes(&wh));
    let (xv, wv) = (TensorView::F32(&xb), TensorView::F32(&wb));

    let nhwc_arena = WorkspaceArena::new();
    let nhwc_us = crate::bench::time_fn(cfg, || {
        let _ = k::conv2d_fwd_im2col_nhwc_view(&xv, &wv, &g,
                                               gemm::DEFAULT_TILE,
                                               &nhwc_arena);
    })
    .median();
    let nhwc_pack_bytes =
        nhwc_arena.stats().pack_traffic_bytes / runs.max(1);

    LayoutPoint {
        name: format!("conv_fwd gemm 1x1 n{}c{}h{}w{}k{}",
                      g.n, g.c, g.h, g.w, g.k),
        nchw_us,
        nhwc_us,
        nchw_pack_bytes,
        nhwc_pack_bytes,
    }
}

/// Measure grouped-direct vs the dedicated depthwise kernel on the
/// g == c exemplar geometry (both NCHW and channels-last variants of
/// the dedicated kernel).
pub fn run_depthwise_bench(cfg: &BenchConfig) -> DepthwisePoint {
    let g = k::ConvGeom { g: 32, p: 1, q: 1,
                          ..k::ConvGeom::dense(4, 32, 14, 14, 32, 3, 3,
                                               1, 1) };
    let mut rng = SplitMix64::new(0xDE97);
    let mut x = vec![0f32; g.n * g.c * g.h * g.w];
    let mut w = vec![0f32; g.k * (g.c / g.g) * g.r * g.s];
    rng.fill_normal_f32(&mut x);
    rng.fill_normal_f32(&mut w);

    let grouped_direct_us = crate::bench::time_fn(cfg, || {
        let _ = k::conv2d_fwd(&x, &w, &g);
    })
    .median();
    let depthwise_nchw_us = crate::bench::time_fn(cfg, || {
        let _ = k::conv2d_fwd_depthwise_nchw(&x, &w, &g);
    })
    .median();

    let mut xh = vec![0f32; x.len()];
    k::nchw_to_nhwc_image(&x, g.n, g.c, g.h, g.w, &mut xh);
    let mut wh = vec![0f32; w.len()];
    k::kcrs_to_krsc(&w, g.k, g.c / g.g, g.r, g.s, &mut wh);
    let depthwise_nhwc_us = crate::bench::time_fn(cfg, || {
        let _ = k::conv2d_fwd_depthwise_nhwc(&xh, &wh, &g, 8);
    })
    .median();

    DepthwisePoint {
        name: format!("conv_fwd depthwise n{}c{}h{}w{}k{}r{}s{}g{}",
                      g.n, g.c, g.h, g.w, g.k, g.r, g.s, g.g),
        grouped_direct_us,
        depthwise_nchw_us,
        depthwise_nhwc_us,
    }
}

/// Run the full kernel-bench suite.
pub fn run_suite(cfg: &BenchConfig) -> KernelBench {
    KernelBench {
        gemm: run_gemm_sweep(cfg),
        arena: run_arena_bench(cfg),
        bf16: run_dtype_sweep(cfg),
        layout: run_layout_bench(cfg),
        depthwise: run_depthwise_bench(cfg),
    }
}

/// The engine-vs-naive speedup on the 256×256×256 acceptance shape: the
/// blocked engine at full capability (packing + register tiling + the
/// panel-granularity thread split — all tentpole features) against the
/// serial naive kernel every non-im2col call site used to run.
pub fn speedup_256(bench: &KernelBench) -> Option<f64> {
    bench
        .gemm
        .iter()
        .find(|p| p.m == 256 && p.k == 256 && p.n == 256)
        .map(|p| {
            p.blocked_gflops.max(p.blocked_par_gflops)
                / p.naive_gflops.max(f64::MIN_POSITIVE)
        })
}

/// The serial blocked-vs-naive speedup on the same shape — what
/// blocking, packing and register tiling buy with no threads at all
/// (the thread split cannot carry this number).
pub fn speedup_256_serial(bench: &KernelBench) -> Option<f64> {
    bench
        .gemm
        .iter()
        .find(|p| p.m == 256 && p.k == 256 && p.n == 256)
        .map(|p| p.speedup)
}

/// Serialize to the `BENCH_kernels.json` schema.
pub fn to_json(bench: &KernelBench) -> Json {
    let gemm_arr: Vec<Json> = bench
        .gemm
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("name", Json::str(p.name.as_str())),
                ("m", Json::num(p.m as f64)),
                ("k", Json::num(p.k as f64)),
                ("n", Json::num(p.n as f64)),
                ("naive_gflops", Json::num(p.naive_gflops)),
                ("blocked_gflops", Json::num(p.blocked_gflops)),
                ("blocked_par_gflops", Json::num(p.blocked_par_gflops)),
                ("speedup_blocked_vs_naive", Json::num(p.speedup)),
            ])
        })
        .collect();
    let bf16_arr: Vec<Json> = bench
        .bf16
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("name", Json::str(p.name.as_str())),
                ("m", Json::num(p.m as f64)),
                ("k", Json::num(p.k as f64)),
                ("n", Json::num(p.n as f64)),
                ("f32_gflops", Json::num(p.f32_gflops)),
                ("bf16_gflops", Json::num(p.bf16_gflops)),
                ("f32_pack_bytes", Json::num(p.f32_pack_bytes as f64)),
                ("bf16_pack_bytes", Json::num(p.bf16_pack_bytes as f64)),
                ("pack_traffic_advantage",
                 Json::num(p.pack_traffic_advantage())),
                ("modeled_advantage", Json::num(p.modeled_advantage)),
            ])
        })
        .collect();
    let a = &bench.arena;
    let arena_obj = Json::obj(vec![
        ("name", Json::str(a.name.as_str())),
        ("warm_arena_us", Json::num(a.warm_arena_us)),
        ("warm_fresh_alloc_us", Json::num(a.warm_fresh_us)),
        ("warm_allocs", Json::num(a.warm_allocs as f64)),
        ("warm_reuses", Json::num(a.warm_reuses as f64)),
        ("arena_speedup", Json::num(a.speedup())),
        ("zero_alloc_warm_path", Json::Bool(a.warm_allocs == 0)),
    ]);
    let l = &bench.layout;
    let layout_obj = Json::obj(vec![
        ("name", Json::str(l.name.as_str())),
        ("nchw_us", Json::num(l.nchw_us)),
        ("nhwc_us", Json::num(l.nhwc_us)),
        ("nchw_pack_bytes", Json::num(l.nchw_pack_bytes as f64)),
        ("nhwc_pack_bytes", Json::num(l.nhwc_pack_bytes as f64)),
        ("pack_traffic_ratio_nchw_over_nhwc",
         Json::num(l.pack_traffic_ratio())),
    ]);
    let d = &bench.depthwise;
    let depthwise_obj = Json::obj(vec![
        ("name", Json::str(d.name.as_str())),
        ("grouped_direct_us", Json::num(d.grouped_direct_us)),
        ("depthwise_nchw_us", Json::num(d.depthwise_nchw_us)),
        ("depthwise_nhwc_us", Json::num(d.depthwise_nhwc_us)),
        // the solver-promotion acceptance: the dedicated kernel must
        // not lose to the grouped-direct fallback it replaced
        ("speedup_vs_grouped_direct", Json::num(d.speedup())),
    ]);
    let mut root = BTreeMap::new();
    root.insert("workload".to_string(),
                Json::str("blocked packed-GEMM engine vs naive triple loop \
                           + workspace-arena serve path"));
    root.insert("profile".to_string(),
                Json::str(if cfg!(debug_assertions) { "debug" }
                          else { "release" }));
    root.insert("gemm".to_string(), Json::Arr(gemm_arr));
    root.insert("arena".to_string(), arena_obj);
    root.insert("bf16".to_string(), Json::Arr(bf16_arr));
    root.insert("layout".to_string(), layout_obj);
    root.insert("depthwise".to_string(), depthwise_obj);
    if let Some(adv) = bench
        .bf16
        .iter()
        .map(DtypePoint::pack_traffic_advantage)
        .min_by(f64::total_cmp)
    {
        // the CI acceptance floor: the bf16 GEMM path must report at
        // least 1.5x the f32 byte traffic advantage in its real
        // packing-traffic counters (the model says exactly 2x)
        root.insert("bf16_pack_traffic_advantage_min".to_string(),
                    Json::num(adv));
    }
    if let Some(s) = speedup_256(bench) {
        root.insert("speedup_256x256x256".to_string(), Json::num(s));
    }
    if let Some(s) = speedup_256_serial(bench) {
        // blocking + register tiling alone, no threads — so the engine
        // speedup above cannot be satisfied by the thread split alone
        root.insert("speedup_256x256x256_serial".to_string(), Json::num(s));
    }
    Json::Obj(root)
}

/// Write `BENCH_kernels.json`.
pub fn write_json(bench: &KernelBench, path: &Path) -> Result<()> {
    std::fs::write(path, to_json(bench).to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_shapes_include_acceptance_shape() {
        assert!(gemm_shapes().iter().any(|(_, m, k, n)|
            (*m, *k, *n) == (256, 256, 256)));
    }

    #[test]
    fn json_schema_round_trips() {
        let bench = KernelBench {
            gemm: vec![GemmPoint {
                name: "256x256x256".into(),
                m: 256, k: 256, n: 256,
                naive_gflops: 1.0,
                blocked_gflops: 4.0,
                blocked_par_gflops: 8.0,
                speedup: 4.0,
            }],
            arena: ArenaPoint {
                name: "conv".into(),
                warm_arena_us: 100.0,
                warm_fresh_us: 130.0,
                warm_allocs: 0,
                warm_reuses: 12,
            },
            bf16: vec![DtypePoint {
                name: "128x128x128".into(),
                m: 128, k: 128, n: 128,
                f32_gflops: 4.0,
                bf16_gflops: 3.5,
                f32_pack_bytes: 131072,
                bf16_pack_bytes: 65536,
                modeled_advantage: 2.0,
            }],
            layout: LayoutPoint {
                name: "conv_fwd gemm 1x1".into(),
                nchw_us: 50.0,
                nhwc_us: 48.0,
                nchw_pack_bytes: 100352,
                nhwc_pack_bytes: 100352,
            },
            depthwise: DepthwisePoint {
                name: "conv_fwd depthwise".into(),
                grouped_direct_us: 90.0,
                depthwise_nchw_us: 60.0,
                depthwise_nhwc_us: 45.0,
            },
        };
        let j = to_json(&bench);
        // engine speedup = best blocked throughput over naive
        assert_eq!(j.get("speedup_256x256x256").and_then(Json::as_f64),
                   Some(8.0));
        assert_eq!(
            j.get("bf16_pack_traffic_advantage_min").and_then(Json::as_f64),
            Some(2.0));
        let text = j.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("gemm").and_then(Json::as_arr).unwrap().len(), 1);
        let arena = back.get("arena").unwrap();
        assert_eq!(arena.get("warm_allocs").and_then(Json::as_f64), Some(0.0));
        let bf = back.get("bf16").and_then(Json::as_arr).unwrap();
        assert_eq!(bf.len(), 1);
        assert_eq!(bf[0].get("pack_traffic_advantage")
                       .and_then(Json::as_f64), Some(2.0));
        let layout = back.get("layout").unwrap();
        assert_eq!(layout.get("pack_traffic_ratio_nchw_over_nhwc")
                         .and_then(Json::as_f64), Some(1.0));
        let dw = back.get("depthwise").unwrap();
        // 90 µs grouped over the best dedicated run (45 µs NHWC)
        assert_eq!(dw.get("speedup_vs_grouped_direct")
                     .and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn depthwise_speedup_guards_divide_by_zero() {
        let d = DepthwisePoint {
            name: "x".into(),
            grouped_direct_us: 1.0,
            depthwise_nchw_us: 0.0,
            depthwise_nhwc_us: 0.0,
        };
        assert_eq!(d.speedup(), 0.0);
    }

    #[test]
    fn dedicated_depthwise_beats_grouped_direct() {
        // a small real measurement: same MAC count, but the dedicated
        // kernel hoists the plane/slice offsets the grouped fallback
        // recomputes per tap — it must not lose to the path it replaced
        let cfg = BenchConfig::default();
        let d = run_depthwise_bench(&cfg);
        assert!(d.speedup() >= 1.0,
                "depthwise {:.1}us/{:.1}us vs grouped {:.1}us",
                d.depthwise_nchw_us, d.depthwise_nhwc_us,
                d.grouped_direct_us);
    }

    #[test]
    fn dtype_point_advantage_guards_divide_by_zero() {
        let p = DtypePoint {
            name: "x".into(),
            m: 1, k: 1, n: 1,
            f32_gflops: 1.0,
            bf16_gflops: 1.0,
            f32_pack_bytes: 8,
            bf16_pack_bytes: 0,
            modeled_advantage: 2.0,
        };
        assert_eq!(p.pack_traffic_advantage(), 0.0);
    }

    #[test]
    fn arena_speedup_guards_divide_by_zero() {
        let a = ArenaPoint {
            name: "x".into(),
            warm_arena_us: 0.0,
            warm_fresh_us: 1.0,
            warm_allocs: 0,
            warm_reuses: 0,
        };
        assert_eq!(a.speedup(), 0.0);
    }
}
