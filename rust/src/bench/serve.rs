//! serve-bench: sweep worker count × batch size × arrival rate over the
//! synthetic CNN serving workload and record p50/p99 latency, throughput
//! and cache hit rates — the scaling evidence for the multi-worker
//! engine — plus the per-dtype warm-serve sweep (bf16 conv twins vs
//! their f32 baselines through the exec-cache hot path) and the
//! adversarial overload traces (burst/diurnal/hot-key/slow-poison)
//! exercising the admission gate, typed shedding, and mid-trace
//! drain/reload. Results serialize to `BENCH_serve.json` (see the
//! `serve-bench` CLI subcommand and the CI smoke job).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::handle::Handle;
use crate::metrics::TimingStats;
use crate::serve::{generate_load, generate_load_opts, run_server,
                   run_server_ctl, Clock, Control, LoadOptions, RealClock,
                   Request, Response, ServeConfig, ServerStats, ShedReason,
                   TenantId, TenantPolicy, TenantQuota, SERVE_INFER_SIG};
use crate::types::{MiopenError, Result};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Requests per sweep point.
    pub requests: usize,
    pub workers: Vec<usize>,
    pub batch_sizes: Vec<usize>,
    /// Poisson arrival rates (req/s); 0.0 = flood (no pacing).
    pub rates: Vec<f64>,
    pub batch_timeout: Duration,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            requests: 512,
            workers: vec![1, 2, 4],
            batch_sizes: vec![16],
            rates: vec![0.0],
            batch_timeout: Duration::from_millis(2),
        }
    }
}

/// One (workers, batch_max, rate) measurement.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub workers: usize,
    pub batch_max: usize,
    pub rate: f64,
    pub served: usize,
    pub p50_us: f64,
    pub p99_us: f64,
    pub req_per_s: f64,
    pub mean_batch: f64,
    pub shard_hits: u64,
    pub shard_lookups: u64,
    pub shard_hit_rate: f64,
}

/// Run the full sweep. Each point drives `cfg.requests` synthetic CNN
/// inference requests through [`run_server`] with a fresh load generator.
pub fn run_sweep(handle: &Handle, cfg: &SweepConfig) -> Result<Vec<SweepPoint>> {
    let manifest = handle.manifest();
    let infer = manifest.require(SERVE_INFER_SIG)?;
    let (_, image_elems, _) = crate::serve::infer_image_layout(infer)?;

    let mut points = Vec::new();
    for &workers in &cfg.workers {
        for &batch_max in &cfg.batch_sizes {
            for &rate in &cfg.rates {
                let serve_cfg = ServeConfig {
                    batch_max,
                    batch_timeout: cfg.batch_timeout,
                    workers,
                    ..Default::default()
                };
                let n = cfg.requests;
                let (stats, served) = std::thread::scope(|scope| {
                    let (tx, rx) = mpsc::channel::<Request>();
                    let server =
                        scope.spawn(|| run_server(handle, &serve_cfg, rx));
                    let resp_rx = generate_load(&tx, n, rate, image_elems,
                                                0x5E47E + workers as u64);
                    drop(tx);
                    let stats = server.join().expect("serve-bench server");
                    let served = resp_rx.iter().count();
                    (stats, served)
                });
                let stats = stats?;
                points.push(SweepPoint {
                    workers,
                    batch_max,
                    rate,
                    served,
                    p50_us: stats.latency.median(),
                    p99_us: stats.latency.p99(),
                    req_per_s: stats.throughput.req_per_s(),
                    mean_batch: stats.throughput.mean_batch_size(),
                    shard_hits: stats.shard_cache.hits,
                    shard_lookups: stats.shard_cache.lookups,
                    shard_hit_rate: stats.shard_cache.hit_rate(),
                });
            }
        }
    }
    Ok(points)
}

/// One per-dtype warm-serve measurement: p50/p99 of repeated warm
/// executions of a conv artifact through the serve hot path (compiled
/// once into the exec cache, then executed per "request").
#[derive(Debug, Clone)]
pub struct DtypeServePoint {
    /// Artifact signature served.
    pub sig: String,
    /// Storage dtype name ("f32" | "bf16").
    pub dtype: String,
    /// Conv algorithm of the artifact.
    pub algo: String,
    /// Warm per-request latency median (µs).
    pub p50_us: f64,
    /// Warm per-request latency 99th percentile (µs).
    pub p99_us: f64,
}

/// The bf16/f32 twin signatures the dtype serve sweep measures: the
/// same problem geometry emitted in both storage dtypes (gemm and
/// winograd on the 3×3 exemplar, gemm on the 1×1).
pub fn dtype_serve_sigs() -> Vec<(&'static str, String)> {
    let g33 = "n4c16h28w28k32r3s3u1v1p1q1l1j1g1";
    let g11 = "n4c16h28w28k16r1s1u1v1p0q0l1j1g1";
    let mut sigs = Vec::new();
    for dt in ["f32", "bf16"] {
        sigs.push((dt, format!("conv_fwd-gemm-{g33}-{dt}")));
        sigs.push((dt, format!("conv_fwd-winograd-{g33}-{dt}")));
        sigs.push((dt, format!("conv_fwd-gemm-{g11}-{dt}")));
    }
    sigs
}

/// Run the per-dtype warm-serve sweep: each artifact is compiled once
/// (the serve engine's warm-shard configuration), then `requests`
/// executions are timed individually for p50/p99. Signatures missing
/// from the manifest are skipped, so the sweep degrades gracefully on
/// reduced artifact sets.
pub fn run_dtype_serve(handle: &Handle, requests: usize)
    -> Result<Vec<DtypeServePoint>> {
    let mut points = Vec::new();
    let manifest = handle.manifest();
    for (dt, sig) in dtype_serve_sigs() {
        let Some(art) = manifest.get(&sig) else {
            continue;
        };
        let algo = art.algo.clone();
        let exe = handle.compile_sig(&sig)?;
        let inputs = handle.random_inputs(&sig)?;
        exe.run(&inputs)?; // warm the arena + any filter caches
        let mut lat = TimingStats::new();
        for _ in 0..requests.max(1) {
            let t = Instant::now();
            exe.run(&inputs)?;
            lat.record(t.elapsed().as_secs_f64() * 1e6);
        }
        points.push(DtypeServePoint {
            sig,
            dtype: dt.to_string(),
            algo,
            p50_us: lat.median(),
            p99_us: lat.p99(),
        });
    }
    Ok(points)
}

/// One per-layout warm-serve measurement: p50/p99 of repeated warm
/// executions of a conv artifact through the serve hot path, with the
/// layout axis ("nchw" | "nhwc") alongside the algorithm.
#[derive(Debug, Clone)]
pub struct LayoutServePoint {
    /// Artifact signature served.
    pub sig: String,
    /// Layout name ("nchw" | "nhwc").
    pub layout: String,
    /// Conv algorithm of the artifact.
    pub algo: String,
    /// Warm per-request latency median (µs).
    pub p50_us: f64,
    /// Warm per-request latency 99th percentile (µs).
    pub p99_us: f64,
}

/// The NHWC/NCHW twin signatures the layout serve sweep measures: the
/// same problem geometry in both layouts across the algorithm zoo —
/// native channels-last kernels (direct, gemm, depthwise) and the
/// transpose-at-boundary fallback (winograd).
pub fn layout_serve_sigs() -> Vec<(&'static str, String)> {
    let g33 = "n4c16h28w28k32r3s3u1v1p1q1l1j1g1";
    let g11 = "n4c16h28w28k16r1s1u1v1p0q0l1j1g1";
    let dw = "n4c32h14w14k32r3s3u1v1p1q1l1j1g32";
    let mut sigs = Vec::new();
    for (lt, tail) in [("nchw", ""), ("nhwc", "-nhwc")] {
        sigs.push((lt, format!("conv_fwd-direct-{g11}-f32{tail}")));
        sigs.push((lt, format!("conv_fwd-gemm-{g33}-f32{tail}")));
        sigs.push((lt, format!("conv_fwd-winograd-{g33}-f32{tail}")));
        sigs.push((lt, format!("conv_fwd-depthwise-{dw}-f32{tail}")));
    }
    sigs
}

/// Run the per-layout warm-serve sweep (same protocol as
/// [`run_dtype_serve`]: compile once, time warm executions, skip
/// signatures missing from the manifest).
pub fn run_layout_serve(handle: &Handle, requests: usize)
    -> Result<Vec<LayoutServePoint>> {
    let mut points = Vec::new();
    let manifest = handle.manifest();
    for (lt, sig) in layout_serve_sigs() {
        let Some(art) = manifest.get(&sig) else {
            continue;
        };
        let algo = art.algo.clone();
        let exe = handle.compile_sig(&sig)?;
        let inputs = handle.random_inputs(&sig)?;
        exe.run(&inputs)?; // warm the arena + any filter caches
        let mut lat = TimingStats::new();
        for _ in 0..requests.max(1) {
            let t = Instant::now();
            exe.run(&inputs)?;
            lat.record(t.elapsed().as_secs_f64() * 1e6);
        }
        points.push(LayoutServePoint {
            sig,
            layout: lt.to_string(),
            algo,
            p50_us: lat.median(),
            p99_us: lat.p99(),
        });
    }
    Ok(points)
}

/// Result of the cold-shape scenario: 100% previously-unseen shapes
/// served in immediate mode (zero find), then the same shapes again
/// after the background refiner upgraded the find-db.
#[derive(Debug, Clone)]
pub struct ColdShapeBench {
    /// Number of cold (previously-unseen) shapes served.
    pub cold_total: usize,
    /// How many of them were verified absent from the find-db before
    /// the cold pass (expected == cold_total on a fresh db).
    pub cold_unseen: usize,
    /// Immediate-selection latency, cold db (µs).
    pub cold_p50_us: f64,
    /// 99th percentile of the cold-selection latency (µs).
    pub cold_p99_us: f64,
    /// Immediate-selection latency after refinement (µs).
    pub warm_p50_us: f64,
    /// 99th percentile of the warm-selection latency (µs).
    pub warm_p99_us: f64,
    /// cold_p99 / warm_p99 — the acceptance gate is ≤ 5.
    pub cold_over_warm_p99: f64,
    /// Shapes the background refiner ran the real find on.
    pub refined: usize,
    /// Enqueue calls dropped by the refiner's exactly-once dedup.
    pub deduped: usize,
    /// Fraction of manifest shapes where the immediate pick (with the
    /// shape's own db entry masked) equals find's winner.
    pub agreement_top1: f64,
    /// Fraction where the pick is within find's top two.
    pub agreement_top2: f64,
    /// Shapes scored for agreement.
    pub agreement_total: usize,
}

/// Run the cold-shape scenario. The figure-6 configs are split in two:
/// even indices are warm-seeded with a real find, odd indices stay
/// unseen and are served via [`crate::immediate::serve_immediate`]:
///
/// 1. **Cold pass** — `rounds` timed selection passes against the
///    half-seeded db (tier 2/3 answers only, zero benchmarking).
/// 2. **Refinement** — one pass with the background refiner enabled;
///    every cold shape gets a real find and the user db is upgraded.
/// 3. **Warm pass** — `rounds` timed passes over the now-complete db
///    (tier-1 hits), giving the cold-vs-warm latency ratio.
/// 4. **Agreement** — for all 16 configs, the immediate pick with the
///    shape's own entry masked (`ignore_self`) is scored against the
///    find winner recorded in the db.
pub fn run_cold_shapes(handle: &Handle, rounds: usize)
    -> Result<ColdShapeBench> {
    use crate::descriptors::{ConvDesc, ConvMode, FilterDesc, TensorDesc};
    use crate::find::ConvProblem;
    use crate::immediate::{serve_immediate, ImmediateOptions};
    use crate::types::DType;

    let configs: Vec<crate::configs::ConvConfig> = crate::configs::fig6_1x1()
        .into_iter()
        .chain(crate::configs::fig6_non1x1())
        .collect();
    let problems: Vec<ConvProblem> = configs
        .iter()
        .map(|c| {
            ConvProblem::forward(
                TensorDesc::nchw(c.n, c.c, c.h, c.w, DType::F32),
                FilterDesc::kcrs(c.k, c.c / c.g, c.r, c.s, DType::F32),
                ConvDesc::new((c.u, c.v), (c.p, c.q), (c.l, c.j),
                              ConvMode::CrossCorrelation, c.g),
            )
        })
        .collect();

    // Warm-seed the even-index shapes so every cold shape has a
    // same-family measured neighbor, as a serving fleet would.
    for p in problems.iter().step_by(2) {
        handle.find_convolution(p)?;
    }
    let cold: Vec<ConvProblem> =
        problems.iter().skip(1).step_by(2).cloned().collect();
    let db = handle.find_db();
    let cold_unseen = cold
        .iter()
        .filter(|p| {
            p.sig().map(|s| db.get(&s.db_key()).is_none()).unwrap_or(false)
        })
        .count();

    let opts = ImmediateOptions::default();
    let rounds = rounds.max(1);

    // 1. Cold pass: timed, no refinement, db state unchanged between
    // rounds so every sample is a genuine cold selection.
    let mut cold_lat = TimingStats::new();
    for _ in 0..rounds {
        let rep = serve_immediate(handle, &cold, &opts, false)?;
        cold_lat.merge(&rep.latency);
    }

    // 2. Refinement pass: the background worker runs the real find on
    // every cold shape and persists the upgraded user db.
    let refine_rep = serve_immediate(handle, &cold, &opts, true)?;

    // 3. Warm pass: same shapes, now tier-1 find-db hits.
    let mut warm_lat = TimingStats::new();
    for _ in 0..rounds {
        let rep = serve_immediate(handle, &cold, &opts, false)?;
        warm_lat.merge(&rep.latency);
    }

    // 4. Immediate-vs-find agreement over the full config set. The
    // pick may not read the shape's own entry (ignore_self), so this
    // scores the estimator, not the cache.
    let masked = ImmediateOptions { ignore_self: true, ..opts };
    let db = handle.find_db();
    let (mut top1, mut top2, mut total) = (0usize, 0usize, 0usize);
    for p in &problems {
        let key = p.sig()?.db_key();
        let Some(records) = db.get(&key) else { continue };
        let Some(winner) = records.first() else { continue };
        let pick = handle.get_solution_opt(p, &masked)?;
        total += 1;
        if pick.algo == winner.algo {
            top1 += 1;
        }
        if records.iter().take(2).any(|r| r.algo == pick.algo) {
            top2 += 1;
        }
    }

    let frac = |n: usize| if total > 0 { n as f64 / total as f64 } else { 0.0 };
    let warm_p99 = warm_lat.p99();
    Ok(ColdShapeBench {
        cold_total: cold.len(),
        cold_unseen,
        cold_p50_us: cold_lat.median(),
        cold_p99_us: cold_lat.p99(),
        warm_p50_us: warm_lat.median(),
        warm_p99_us: warm_p99,
        cold_over_warm_p99: if warm_p99 > 0.0 {
            cold_lat.p99() / warm_p99
        } else {
            f64::NAN
        },
        refined: refine_rep.refiner.refined,
        deduped: refine_rep.refiner.deduped,
        agreement_top1: frac(top1),
        agreement_top2: frac(top2),
        agreement_total: total,
    })
}

// ---------------------------------------------------------------------------
// Adversarial overload traces
// ---------------------------------------------------------------------------

/// The adversarial traffic shapes driven against the continuous-batching
/// engine (ISSUE: "overload" section of BENCH_serve.json).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// One sustained burst at 2× measured capacity with deadlines on
    /// every request, plus a drain/reload fired mid-trace.
    Burst,
    /// Three phases — ramp up, peak above capacity, cool down — the
    /// day/night traffic curve.
    Diurnal,
    /// 80% of requests share one affinity key at ~1.2× capacity; the
    /// per-worker shard hit rates must stay warm anyway.
    HotKey,
    /// Every 5th request is malformed; the gate must shed them without
    /// a worker ever dying (the old engine let bad requests kill the
    /// pool).
    SlowPoison,
}

impl TraceKind {
    /// CLI spelling (`burst` | `diurnal` | `hotkey` | `poison`).
    pub fn parse(s: &str) -> Option<TraceKind> {
        match s {
            "burst" => Some(TraceKind::Burst),
            "diurnal" => Some(TraceKind::Diurnal),
            "hotkey" | "hot-key" => Some(TraceKind::HotKey),
            "poison" | "slow-poison" => Some(TraceKind::SlowPoison),
            _ => None,
        }
    }

    /// Stable name used in JSON output.
    pub fn as_str(self) -> &'static str {
        match self {
            TraceKind::Burst => "burst",
            TraceKind::Diurnal => "diurnal",
            TraceKind::HotKey => "hotkey",
            TraceKind::SlowPoison => "slow-poison",
        }
    }

    /// Every trace, in JSON output order.
    pub fn all() -> Vec<TraceKind> {
        vec![TraceKind::Burst, TraceKind::Diurnal, TraceKind::HotKey,
             TraceKind::SlowPoison]
    }
}

/// Engine shape for the overload traces (deliberately small so the
/// capacity flood saturates quickly).
#[derive(Debug, Clone)]
pub struct OverloadConfig {
    /// Requests per trace.
    pub requests: usize,
    pub workers: usize,
    pub batch_max: usize,
    pub batch_timeout: Duration,
    /// Admission queue bound handed to [`ServeConfig::queue_cap`].
    pub queue_cap: usize,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        Self {
            requests: 192,
            workers: 2,
            batch_max: 8,
            batch_timeout: Duration::from_millis(2),
            queue_cap: 256,
        }
    }
}

/// Outcome of one adversarial trace — everything the CI overload gates
/// read out of `BENCH_serve.json`.
#[derive(Debug, Clone)]
pub struct TraceResult {
    /// [`TraceKind::as_str`] of the trace.
    pub trace: String,
    /// Requests submitted.
    pub requests: usize,
    /// Flood capacity (req/s) measured immediately before the trace.
    pub capacity_req_s: f64,
    /// Relative deadline stamped on the trace's requests (µs).
    pub deadline_us: u64,
    /// Requests answered with [`Response::Done`].
    pub done: usize,
    /// Requests answered with [`Response::Shed`] (any reason).
    pub shed: usize,
    /// Sheds at dispatch ([`ShedReason::Expired`]).
    pub shed_expired: usize,
    /// Sheds of malformed requests (slow-poison accounting).
    pub shed_malformed: usize,
    /// Every id answered exactly once, Done + Shed == requests.
    pub exactly_once: bool,
    /// In-deadline completions per second.
    pub goodput_req_s: f64,
    /// goodput / capacity — the burst gate is ≥ 0.9.
    pub goodput_over_capacity: f64,
    /// p50 latency of requests that were actually served (µs).
    pub admitted_p50_us: f64,
    /// p99 latency of served requests (µs) — bounded by the deadline.
    pub admitted_p99_us: f64,
    /// shed / requests.
    pub shed_rate: f64,
    /// Responses undeliverable because the client hung up.
    pub client_gone: u64,
    /// Successful drain/reloads applied mid-trace (burst fires one).
    pub reloads: u64,
    /// Least-loaded worker's fraction of served requests (hot-key load
    /// balance; 0 when nothing was served or a single worker ran).
    pub min_worker_share: f64,
    /// Merged per-worker exec-cache shard hit rate.
    pub shard_hit_rate: f64,
}

/// Measure sustained flood capacity (req/s): no pacing, no deadlines,
/// same engine shape as the traces.
pub fn measure_capacity(handle: &Handle, cfg: &OverloadConfig)
    -> Result<f64> {
    let manifest = handle.manifest();
    let infer = manifest.require(SERVE_INFER_SIG)?;
    let (_, image_elems, _) = crate::serve::infer_image_layout(infer)?;
    drop(manifest);
    let serve_cfg = ServeConfig {
        batch_max: cfg.batch_max,
        batch_timeout: cfg.batch_timeout,
        workers: cfg.workers,
        queue_cap: cfg.queue_cap.max(cfg.requests),
        ..Default::default()
    };
    let n = cfg.requests.max(16);
    let stats = std::thread::scope(|scope| {
        let (tx, rx) = mpsc::channel::<Request>();
        let server = scope.spawn(|| run_server(handle, &serve_cfg, rx));
        let resp_rx = generate_load(&tx, n, 0.0, image_elems, 0xCA9);
        drop(tx);
        let stats = server.join().expect("capacity server");
        let _ = resp_rx.iter().count();
        stats
    })?;
    Ok(stats.throughput.req_per_s())
}

/// (requests, offered rate req/s) phases for a trace at capacity `cap`.
fn trace_phases(kind: TraceKind, n: usize, cap: f64) -> Vec<(usize, f64)> {
    match kind {
        // two half-phases so the mid-trace reload fires between them,
        // while the second half of the burst is still being submitted
        TraceKind::Burst => vec![(n / 2, 2.0 * cap), (n - n / 2, 2.0 * cap)],
        TraceKind::Diurnal => {
            let third = n / 3;
            vec![
                (third, 0.6 * cap),
                (third, 1.8 * cap),
                (n - 2 * third, 0.3 * cap),
            ]
        }
        TraceKind::HotKey => vec![(n, 1.2 * cap)],
        TraceKind::SlowPoison => vec![(n, 2.0 * cap)],
    }
}

fn trace_load_options(kind: TraceKind, deadline_us: u64) -> LoadOptions {
    let mut opts = LoadOptions {
        deadline_us: Some(deadline_us),
        ..LoadOptions::default()
    };
    match kind {
        TraceKind::Burst => {
            // mixed priorities so the p50/p99-per-class stats populate
            opts.priority_weights = [0.2, 0.6, 0.2];
        }
        TraceKind::Diurnal => {}
        TraceKind::HotKey => opts.hot_fraction = 0.8,
        TraceKind::SlowPoison => opts.malformed_every = 5,
    }
    opts
}

/// Run one adversarial trace against a live engine. The calling thread
/// paces the submissions (Poisson at each phase's offered rate) while
/// the engine runs on a scoped thread; the burst trace additionally
/// fires a [`Control::Reload`] once half the requests are in flight.
pub fn run_trace(handle: &Handle, kind: TraceKind, cfg: &OverloadConfig,
                 capacity: f64) -> Result<TraceResult> {
    let manifest = handle.manifest();
    let infer = manifest.require(SERVE_INFER_SIG)?;
    let (_, image_elems, _) = crate::serve::infer_image_layout(infer)?;
    drop(manifest);
    let serve_cfg = ServeConfig {
        batch_max: cfg.batch_max,
        batch_timeout: cfg.batch_timeout,
        workers: cfg.workers,
        queue_cap: cfg.queue_cap,
        ..Default::default()
    };
    let n = cfg.requests.max(8);
    let cap = capacity.max(1.0);
    // deadline = ten batch-service periods of headroom at measured
    // capacity, clamped to [50ms, 2s] so noisy hosts neither shed
    // everything nor never shed
    let per_batch_us = cfg.batch_max as f64 * 1e6 / cap;
    let deadline_us = ((per_batch_us * 10.0) as u64).clamp(50_000, 2_000_000);
    let opts = trace_load_options(kind, deadline_us);
    let phases = trace_phases(kind, n, cap);
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());

    let (tx, rx) = mpsc::channel::<Request>();
    let (ctl_tx, ctl_rx) = mpsc::channel();
    let (stats, responses, reload_done) = std::thread::scope(
        |scope| -> Result<(ServerStats, Vec<Response>, Option<Result<()>>)> {
            let server =
                scope.spawn(|| run_server_ctl(handle, &serve_cfg, rx, ctl_rx));
            let mut resp_rxs = Vec::new();
            let mut sent = 0usize;
            let mut reload_rx = None;
            let reload_at =
                if kind == TraceKind::Burst { n / 2 } else { usize::MAX };
            for (i, &(pn, rate)) in phases.iter().enumerate() {
                // ids restart per generate_load_opts call, so give each
                // phase its own response channel and offset ids later
                resp_rxs.push(generate_load_opts(
                    &tx, pn, rate, image_elems,
                    0xBEA7 + i as u64, &clock, &opts));
                sent += pn;
                if reload_rx.is_none() && sent >= reload_at {
                    // fire the drain/reload while the queue is loaded;
                    // completion is checked after the trace drains
                    let (dtx, drx) = mpsc::channel();
                    let _ = ctl_tx.send(Control::Reload {
                        apply: Box::new(|h: &Handle| h.reload_artifacts()),
                        done: dtx,
                    });
                    reload_rx = Some(drx);
                }
            }
            drop(tx);
            let stats = server.join().expect("trace server")?;
            let mut responses = Vec::with_capacity(n);
            for rx in resp_rxs {
                responses.extend(rx.iter());
            }
            let reload_done = reload_rx.map(|drx| {
                drx.recv().unwrap_or_else(|_| {
                    Err(MiopenError::Internal(
                        "reload acknowledgement channel closed".into()))
                })
            });
            Ok((stats, responses, reload_done))
        })?;

    if let Some(r) = reload_done {
        r?; // a failed mid-trace reload fails the trace
    }

    // exactly-once: every phase numbered its ids 0..pn, so count
    // responses per (phase-local id, phase) — the per-phase receivers
    // already partition them; here the concatenated list must answer
    // every submitted request exactly once overall.
    let mut done_lat = TimingStats::new();
    let (mut done, mut shed) = (0usize, 0usize);
    let (mut shed_expired, mut shed_malformed) = (0usize, 0usize);
    let mut per_worker_done = vec![0u64; cfg.workers.max(1)];
    for r in &responses {
        match r {
            Response::Done(c) => {
                done += 1;
                done_lat.record(c.latency_us);
                if let Some(slot) = per_worker_done.get_mut(c.worker) {
                    *slot += 1;
                }
            }
            Response::Shed(s) => {
                shed += 1;
                match s.reason {
                    ShedReason::Expired => shed_expired += 1,
                    ShedReason::Malformed => shed_malformed += 1,
                    _ => {}
                }
            }
        }
    }
    let exactly_once = done + shed == n && responses.len() == n;
    let min_worker_share = if done > 0 && cfg.workers > 1 {
        per_worker_done.iter().copied().min().unwrap_or(0) as f64
            / done as f64
    } else {
        0.0
    };
    let snap = &stats.snapshot;
    Ok(TraceResult {
        trace: kind.as_str().to_string(),
        requests: n,
        capacity_req_s: cap,
        deadline_us,
        done,
        shed,
        shed_expired,
        shed_malformed,
        exactly_once,
        goodput_req_s: snap.goodput_req_s,
        goodput_over_capacity: snap.goodput_req_s / cap,
        admitted_p50_us: done_lat.median(),
        admitted_p99_us: done_lat.p99(),
        shed_rate: shed as f64 / n as f64,
        client_gone: snap.client_gone,
        reloads: snap.reloads,
        min_worker_share,
        shard_hit_rate: stats.shard_cache.hit_rate(),
    })
}

/// Measure capacity once, then run every requested trace against it.
pub fn run_overload(handle: &Handle, kinds: &[TraceKind],
                    cfg: &OverloadConfig) -> Result<Vec<TraceResult>> {
    let capacity = measure_capacity(handle, cfg)?;
    kinds
        .iter()
        .map(|&k| run_trace(handle, k, cfg, capacity))
        .collect()
}

// ---------------------------------------------------------------------------
// Two-tenant isolation trace
// ---------------------------------------------------------------------------

/// Outcome of the two-tenant flood trace (ROADMAP item 3's acceptance
/// gate): tenant A floods at 10× its rate quota while tenant B sends a
/// steady in-quota stream; B is first measured running alone on an
/// identical engine, and isolation is B's contended goodput/p99
/// relative to that solo baseline.
#[derive(Debug, Clone)]
pub struct TwoTenantResult {
    /// Requests tenant A (the flooder) submitted.
    pub requests_a: usize,
    /// Requests tenant B (the in-quota tenant) submitted.
    pub requests_b: usize,
    /// Flood capacity (req/s) measured before the trace.
    pub capacity_req_s: f64,
    /// Relative deadline stamped on every request (µs).
    pub deadline_us: u64,
    /// Tenant A's token-bucket rate quota (req/s); A offers 10× this.
    pub quota_a_req_s: f64,
    /// Tenant B in-deadline completions per second, running alone.
    pub solo_goodput_req_s: f64,
    /// Tenant B served-request p50, running alone (µs).
    pub solo_p50_us: f64,
    /// Tenant B served-request p99, running alone (µs).
    pub solo_p99_us: f64,
    /// Tenant B in-deadline completions per second, under A's flood.
    pub contended_goodput_req_s: f64,
    /// Tenant B served-request p50 under A's flood (µs).
    pub contended_p50_us: f64,
    /// Tenant B served-request p99 under A's flood (µs).
    pub contended_p99_us: f64,
    /// contended / solo goodput — the CI gate is ≥ 0.95.
    pub goodput_ratio: f64,
    /// contended / solo p99 — the CI gate is ≤ 1.2 (with a small
    /// absolute cushion for sub-ms baselines).
    pub p99_ratio: f64,
    /// Tenant A requests served (its in-quota trickle).
    pub done_a: usize,
    /// Tenant A requests shed with `quota_exceeded` — must be > 0 or
    /// the quota never engaged and the trace proved nothing.
    pub shed_quota_a: u64,
    /// Tenant B requests shed with `quota_exceeded` — must be 0: an
    /// in-quota tenant is never quota-shed.
    pub shed_quota_b: u64,
    /// Every id in both runs answered exactly once.
    pub exactly_once: bool,
}

/// Feed one engine from several concurrent load-generator threads (one
/// per stream) and collect each stream's responses separately.
fn run_tenant_streams(handle: &Handle, serve_cfg: &ServeConfig,
                      image_elems: usize,
                      streams: Vec<(usize, f64, LoadOptions, u64)>)
    -> Result<(Vec<Vec<Response>>, ServerStats)> {
    let clock: Arc<dyn Clock> = Arc::new(RealClock::new());
    let (tx, rx) = mpsc::channel::<Request>();
    let (responses, stats) = std::thread::scope(|scope| {
        let server = scope.spawn(|| run_server(handle, serve_cfg, rx));
        let mut gens = Vec::new();
        for (n, rate, opts, seed) in streams {
            let tx = tx.clone();
            let clock = clock.clone();
            gens.push(scope.spawn(move || {
                generate_load_opts(&tx, n, rate, image_elems, seed,
                                   &clock, &opts)
            }));
        }
        drop(tx);
        let rxs: Vec<_> = gens
            .into_iter()
            .map(|g| g.join().expect("two-tenant load generator"))
            .collect();
        let stats = server.join().expect("two-tenant server");
        let responses: Vec<Vec<Response>> = rxs
            .into_iter()
            .map(|rx| rx.iter().collect())
            .collect();
        (responses, stats)
    });
    Ok((responses, stats?))
}

/// (in-deadline done, done, shed, quota sheds, served-latency stats)
/// for one tenant's response stream. In-deadline is judged from the
/// served latency against the relative deadline, which is exactly how
/// the engine stamps absolute deadlines.
fn tenant_outcome(responses: &[Response], deadline_us: u64)
    -> (usize, usize, usize, u64, TimingStats) {
    let mut lat = TimingStats::new();
    let (mut in_deadline, mut done, mut shed) = (0usize, 0usize, 0usize);
    let mut shed_quota = 0u64;
    for r in responses {
        match r {
            Response::Done(c) => {
                done += 1;
                lat.record(c.latency_us);
                if c.latency_us <= deadline_us as f64 {
                    in_deadline += 1;
                }
            }
            Response::Shed(s) => {
                shed += 1;
                if s.reason == ShedReason::QuotaExceeded {
                    shed_quota += 1;
                }
            }
        }
    }
    (in_deadline, done, shed, shed_quota, lat)
}

/// Run the two-tenant isolation trace. Tenant A (id 1) gets a rate
/// quota of capacity/4 with a small burst and a depth cap, and floods
/// at 10× that quota; tenant B (id 2) is unlimited and paced steadily
/// at capacity/4 — comfortably inside what the engine can serve.
/// Tenant B runs once alone and once under the flood on identical
/// engines; isolation holds when its goodput and p99 are statistically
/// unchanged (`goodput_ratio`/`p99_ratio`).
pub fn run_two_tenant(handle: &Handle, cfg: &OverloadConfig,
                      capacity: f64) -> Result<TwoTenantResult> {
    let manifest = handle.manifest();
    let infer = manifest.require(SERVE_INFER_SIG)?;
    let (_, image_elems, _) = crate::serve::infer_image_layout(infer)?;
    drop(manifest);

    let cap = capacity.max(1.0);
    let quota_a = cap / 4.0;
    let rate_b = cap / 4.0;
    let n_b = cfg.requests.max(8);
    // A floods at 10× quota for as long as B's stream lasts:
    // (10 × cap/4) × (n_b / (cap/4)) = 10 × n_b requests
    let n_a = 10 * n_b;
    let per_batch_us = cfg.batch_max as f64 * 1e6 / cap;
    let deadline_us =
        ((per_batch_us * 10.0) as u64).clamp(50_000, 2_000_000);

    let mut policy = TenantPolicy::default();
    policy.set(TenantId(1), TenantQuota {
        weight: 1,
        rate_per_s: quota_a,
        burst: 16.0,
        depth_cap: 64,
    });
    let serve_cfg = ServeConfig {
        batch_max: cfg.batch_max,
        batch_timeout: cfg.batch_timeout,
        workers: cfg.workers,
        queue_cap: cfg.queue_cap,
        tenants: policy,
        ..Default::default()
    };

    let opts_for = |tenant: u32| LoadOptions {
        deadline_us: Some(deadline_us),
        tenants: vec![TenantId(tenant)],
        ..LoadOptions::default()
    };

    // B's offered window is the same in both runs, so goodput compares
    // completions over the identical denominator
    let window_s = n_b as f64 / rate_b;

    // solo baseline: tenant B alone on an identical engine
    let (solo_resp, _solo_stats) = run_tenant_streams(
        handle, &serve_cfg, image_elems,
        vec![(n_b, rate_b, opts_for(2), 0x7E4A17)])?;
    let (solo_good, solo_done, solo_shed, solo_quota_shed, solo_lat) =
        tenant_outcome(&solo_resp[0], deadline_us);

    // contended: A floods from its own thread while B paces steadily
    let (resp, _stats) = run_tenant_streams(
        handle, &serve_cfg, image_elems,
        vec![(n_a, 10.0 * quota_a, opts_for(1), 0xF100D),
             (n_b, rate_b, opts_for(2), 0x7E4A17)])?;
    let (_, done_a, shed_a, shed_quota_a, _) =
        tenant_outcome(&resp[0], deadline_us);
    let (cont_good, done_b, shed_b, shed_quota_b, cont_lat) =
        tenant_outcome(&resp[1], deadline_us);

    let exactly_once = solo_done + solo_shed == n_b
        && solo_resp[0].len() == n_b
        && done_a + shed_a == n_a && resp[0].len() == n_a
        && done_b + shed_b == n_b && resp[1].len() == n_b;

    let solo_goodput = solo_good as f64 / window_s;
    let cont_goodput = cont_good as f64 / window_s;
    let solo_p99 = solo_lat.p99();
    let cont_p99 = cont_lat.p99();
    let b_tenant_quota_sheds = solo_quota_shed + shed_quota_b;
    Ok(TwoTenantResult {
        requests_a: n_a,
        requests_b: n_b,
        capacity_req_s: cap,
        deadline_us,
        quota_a_req_s: quota_a,
        solo_goodput_req_s: solo_goodput,
        solo_p50_us: solo_lat.median(),
        solo_p99_us: solo_p99,
        contended_goodput_req_s: cont_goodput,
        contended_p50_us: cont_lat.median(),
        contended_p99_us: cont_p99,
        goodput_ratio: if solo_goodput > 0.0 {
            cont_goodput / solo_goodput
        } else {
            0.0
        },
        p99_ratio: if solo_p99 > 0.0 { cont_p99 / solo_p99 } else { 0.0 },
        done_a,
        shed_quota_a,
        shed_quota_b: b_tenant_quota_sheds,
        exactly_once,
    })
}

/// Throughput ratio of `workers_b` over `workers_a`, compared only
/// between points with the *same* (batch_max, rate) configuration so
/// the number measures worker scaling, not batching differences. The
/// flood-rate pairing is preferred (it saturates the pool); otherwise
/// the best matched ratio is reported.
pub fn speedup(points: &[SweepPoint], workers_a: usize, workers_b: usize)
    -> Option<f64> {
    let mut best: Option<f64> = None;
    for pa in points.iter().filter(|p| p.workers == workers_a) {
        let matched = points.iter().find(|p| {
            p.workers == workers_b
                && p.batch_max == pa.batch_max
                && p.rate == pa.rate
        });
        if let Some(pb) = matched {
            if pa.req_per_s > 0.0 {
                let s = pb.req_per_s / pa.req_per_s;
                if pa.rate <= 0.0 {
                    return Some(s); // flood pairing wins outright
                }
                best = Some(best.map_or(s, |x: f64| x.max(s)));
            }
        }
    }
    best
}

pub fn to_json(points: &[SweepPoint], dtype: &[DtypeServePoint],
               layout: &[LayoutServePoint],
               cold: Option<&ColdShapeBench>,
               overload: &[TraceResult],
               two_tenant: Option<&TwoTenantResult>) -> Json {
    let arr: Vec<Json> = points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("workers", Json::num(p.workers as f64)),
                ("batch_max", Json::num(p.batch_max as f64)),
                ("rate_req_s", Json::num(p.rate)),
                ("served", Json::num(p.served as f64)),
                ("p50_latency_us", Json::num(p.p50_us)),
                ("p99_latency_us", Json::num(p.p99_us)),
                ("throughput_req_s", Json::num(p.req_per_s)),
                ("mean_batch_size", Json::num(p.mean_batch)),
                ("shard_cache_hits", Json::num(p.shard_hits as f64)),
                ("shard_cache_lookups", Json::num(p.shard_lookups as f64)),
                ("shard_cache_hit_rate", Json::num(p.shard_hit_rate)),
            ])
        })
        .collect();
    let dtype_arr: Vec<Json> = dtype
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("sig", Json::str(p.sig.as_str())),
                ("dtype", Json::str(p.dtype.as_str())),
                ("algo", Json::str(p.algo.as_str())),
                ("p50_latency_us", Json::num(p.p50_us)),
                ("p99_latency_us", Json::num(p.p99_us)),
            ])
        })
        .collect();
    let layout_arr: Vec<Json> = layout
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("sig", Json::str(p.sig.as_str())),
                ("layout", Json::str(p.layout.as_str())),
                ("algo", Json::str(p.algo.as_str())),
                ("p50_latency_us", Json::num(p.p50_us)),
                ("p99_latency_us", Json::num(p.p99_us)),
            ])
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("workload".to_string(),
                Json::str("synthetic CNN inference (cnn_infer-f32)"));
    root.insert("points".to_string(), Json::Arr(arr));
    root.insert("dtype_serve".to_string(), Json::Arr(dtype_arr));
    root.insert("layout_serve".to_string(), Json::Arr(layout_arr));
    if let Some(s) = speedup(points, 1, 4) {
        root.insert("speedup_4w_over_1w".to_string(), Json::num(s));
    }
    if let Some(s) = speedup(points, 1, 2) {
        root.insert("speedup_2w_over_1w".to_string(), Json::num(s));
    }
    if let Some(c) = cold {
        root.insert("cold_shapes".to_string(), Json::obj(vec![
            ("cold_total", Json::num(c.cold_total as f64)),
            ("cold_unseen", Json::num(c.cold_unseen as f64)),
            ("cold_p50_us", Json::num(c.cold_p50_us)),
            ("cold_p99_us", Json::num(c.cold_p99_us)),
            ("warm_p50_us", Json::num(c.warm_p50_us)),
            ("warm_p99_us", Json::num(c.warm_p99_us)),
            ("cold_over_warm_p99", Json::num(c.cold_over_warm_p99)),
            ("refined", Json::num(c.refined as f64)),
            ("deduped", Json::num(c.deduped as f64)),
            ("agreement_top1", Json::num(c.agreement_top1)),
            ("agreement_top2", Json::num(c.agreement_top2)),
            ("agreement_total", Json::num(c.agreement_total as f64)),
        ]));
    }
    if !overload.is_empty() || two_tenant.is_some() {
        let arr: Vec<Json> = overload
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("trace", Json::str(t.trace.as_str())),
                    ("requests", Json::num(t.requests as f64)),
                    ("capacity_req_s", Json::num(t.capacity_req_s)),
                    ("deadline_us", Json::num(t.deadline_us as f64)),
                    ("done", Json::num(t.done as f64)),
                    ("shed", Json::num(t.shed as f64)),
                    ("shed_expired", Json::num(t.shed_expired as f64)),
                    ("shed_malformed",
                     Json::num(t.shed_malformed as f64)),
                    ("exactly_once", Json::Bool(t.exactly_once)),
                    ("goodput_req_s", Json::num(t.goodput_req_s)),
                    ("goodput_over_capacity",
                     Json::num(t.goodput_over_capacity)),
                    ("admitted_p50_us", Json::num(t.admitted_p50_us)),
                    ("admitted_p99_us", Json::num(t.admitted_p99_us)),
                    ("shed_rate", Json::num(t.shed_rate)),
                    ("client_gone", Json::num(t.client_gone as f64)),
                    ("reloads", Json::num(t.reloads as f64)),
                    ("min_worker_share", Json::num(t.min_worker_share)),
                    ("shard_hit_rate", Json::num(t.shard_hit_rate)),
                ])
            })
            .collect();
        let mut section = BTreeMap::new();
        section.insert("traces".to_string(), Json::Arr(arr));
        if let Some(tt) = two_tenant {
            section.insert("two_tenant".to_string(), Json::obj(vec![
                ("requests_a", Json::num(tt.requests_a as f64)),
                ("requests_b", Json::num(tt.requests_b as f64)),
                ("capacity_req_s", Json::num(tt.capacity_req_s)),
                ("deadline_us", Json::num(tt.deadline_us as f64)),
                ("quota_a_req_s", Json::num(tt.quota_a_req_s)),
                ("solo_goodput_req_s",
                 Json::num(tt.solo_goodput_req_s)),
                ("solo_p50_us", Json::num(tt.solo_p50_us)),
                ("solo_p99_us", Json::num(tt.solo_p99_us)),
                ("contended_goodput_req_s",
                 Json::num(tt.contended_goodput_req_s)),
                ("contended_p50_us", Json::num(tt.contended_p50_us)),
                ("contended_p99_us", Json::num(tt.contended_p99_us)),
                ("goodput_ratio", Json::num(tt.goodput_ratio)),
                ("p99_ratio", Json::num(tt.p99_ratio)),
                ("done_a", Json::num(tt.done_a as f64)),
                ("shed_quota_a", Json::num(tt.shed_quota_a as f64)),
                ("shed_quota_b", Json::num(tt.shed_quota_b as f64)),
                ("exactly_once", Json::Bool(tt.exactly_once)),
            ]));
        }
        root.insert("overload".to_string(), Json::Obj(section));
    }
    Json::Obj(root)
}

/// Serialize and write `BENCH_serve.json` (worker sweep + per-dtype and
/// per-layout warm-serve points + the cold-shape immediate-mode
/// scenario + the adversarial overload traces under `overload.traces`
/// and the two-tenant isolation trace under `overload.two_tenant`).
pub fn write_json(points: &[SweepPoint], dtype: &[DtypeServePoint],
                  layout: &[LayoutServePoint],
                  cold: Option<&ColdShapeBench>, overload: &[TraceResult],
                  two_tenant: Option<&TwoTenantResult>,
                  path: &Path) -> Result<()> {
    std::fs::write(path,
                   to_json(points, dtype, layout, cold, overload,
                           two_tenant)
                       .to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(workers: usize, batch_max: usize, rate: f64, req_per_s: f64)
        -> SweepPoint {
        SweepPoint {
            workers,
            batch_max,
            rate,
            served: 10,
            p50_us: 100.0,
            p99_us: 200.0,
            req_per_s,
            mean_batch: 8.0,
            shard_hits: 9,
            shard_lookups: 10,
            shard_hit_rate: 0.9,
        }
    }

    #[test]
    fn speedup_compares_matching_configs_only() {
        // 4-worker@batch32 is fastest overall but must NOT be compared
        // against 1-worker@batch16 — only equal (batch, rate) pairs count
        let pts = vec![
            point(1, 16, 0.0, 100.0),
            point(4, 16, 0.0, 250.0),
            point(4, 32, 0.0, 900.0),
        ];
        let s = speedup(&pts, 1, 4).unwrap();
        assert!((s - 2.5).abs() < 1e-9);
        assert!(speedup(&pts, 1, 8).is_none());
    }

    #[test]
    fn speedup_prefers_flood_pairing() {
        let pts = vec![
            point(1, 16, 100.0, 50.0),
            point(4, 16, 100.0, 60.0),
            point(1, 16, 0.0, 100.0),
            point(4, 16, 0.0, 300.0),
        ];
        let s = speedup(&pts, 1, 4).unwrap();
        assert!((s - 3.0).abs() < 1e-9, "flood pairing must win: {s}");
    }

    #[test]
    fn json_has_points_and_speedup() {
        let pts = vec![point(1, 16, 0.0, 100.0), point(4, 16, 0.0, 250.0)];
        let dtype = vec![DtypeServePoint {
            sig: "conv_fwd-gemm-x-bf16".into(),
            dtype: "bf16".into(),
            algo: "gemm".into(),
            p50_us: 90.0,
            p99_us: 140.0,
        }];
        let cold = ColdShapeBench {
            cold_total: 8,
            cold_unseen: 8,
            cold_p50_us: 50.0,
            cold_p99_us: 120.0,
            warm_p50_us: 40.0,
            warm_p99_us: 60.0,
            cold_over_warm_p99: 2.0,
            refined: 8,
            deduped: 0,
            agreement_top1: 0.875,
            agreement_top2: 1.0,
            agreement_total: 16,
        };
        let layout = vec![LayoutServePoint {
            sig: "conv_fwd-gemm-x-f32-nhwc".into(),
            layout: "nhwc".into(),
            algo: "gemm".into(),
            p50_us: 95.0,
            p99_us: 150.0,
        }];
        let j = to_json(&pts, &dtype, &layout, Some(&cold), &[], None);
        assert_eq!(j.get("points").and_then(Json::as_arr).unwrap().len(), 2);
        let s = j.get("speedup_4w_over_1w").and_then(Json::as_f64).unwrap();
        assert!((s - 2.5).abs() < 1e-9);
        // round-trips through the codec
        let text = j.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("points").and_then(Json::as_arr).unwrap().len(),
                   2);
        let ds = back.get("dtype_serve").and_then(Json::as_arr).unwrap();
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].get("dtype").and_then(Json::as_str),
                   Some("bf16"));
        let ls = back.get("layout_serve").and_then(Json::as_arr).unwrap();
        assert_eq!(ls.len(), 1);
        assert_eq!(ls[0].get("layout").and_then(Json::as_str),
                   Some("nhwc"));
        let cs = back.get("cold_shapes").unwrap();
        assert_eq!(cs.get("agreement_top1").and_then(Json::as_f64),
                   Some(0.875));
        assert_eq!(cs.get("cold_over_warm_p99").and_then(Json::as_f64),
                   Some(2.0));
    }

    #[test]
    fn json_omits_cold_shapes_when_absent() {
        let j = to_json(&[], &[], &[], None, &[], None);
        assert!(j.get("cold_shapes").is_none());
        assert!(j.get("overload").is_none(),
                "empty overload must not emit a section");
    }

    #[test]
    fn trace_kind_parses_cli_spellings() {
        assert_eq!(TraceKind::parse("burst"), Some(TraceKind::Burst));
        assert_eq!(TraceKind::parse("hot-key"), Some(TraceKind::HotKey));
        assert_eq!(TraceKind::parse("poison"),
                   Some(TraceKind::SlowPoison));
        assert_eq!(TraceKind::parse("nope"), None);
        for k in TraceKind::all() {
            assert_eq!(TraceKind::parse(k.as_str()), Some(k));
        }
    }

    #[test]
    fn trace_phases_cover_all_requests() {
        for k in TraceKind::all() {
            let total: usize = trace_phases(k, 100, 50.0)
                .iter()
                .map(|&(n, _)| n)
                .sum();
            assert_eq!(total, 100, "{} drops requests", k.as_str());
        }
        // the burst offers 2x capacity
        let burst = trace_phases(TraceKind::Burst, 100, 50.0);
        assert!(burst.iter().all(|&(_, r)| (r - 100.0).abs() < 1e-9));
    }

    #[test]
    fn overload_json_round_trips() {
        let t = TraceResult {
            trace: "burst".into(),
            requests: 192,
            capacity_req_s: 800.0,
            deadline_us: 120_000,
            done: 150,
            shed: 42,
            shed_expired: 5,
            shed_malformed: 0,
            exactly_once: true,
            goodput_req_s: 780.0,
            goodput_over_capacity: 0.975,
            admitted_p50_us: 9_000.0,
            admitted_p99_us: 80_000.0,
            shed_rate: 42.0 / 192.0,
            client_gone: 0,
            reloads: 1,
            min_worker_share: 0.4,
            shard_hit_rate: 0.99,
        };
        let j = to_json(&[], &[], &[], None, &[t], None);
        let back = crate::util::json::parse(&j.to_string()).unwrap();
        let section = back.get("overload").unwrap();
        let arr = section.get("traces").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 1);
        let b = &arr[0];
        assert_eq!(b.get("trace").and_then(Json::as_str), Some("burst"));
        assert_eq!(b.get("exactly_once").and_then(Json::as_bool),
                   Some(true));
        assert_eq!(b.get("reloads").and_then(Json::as_i64), Some(1));
        let g = b.get("goodput_over_capacity")
            .and_then(Json::as_f64)
            .unwrap();
        assert!((g - 0.975).abs() < 1e-9);
        // no two_tenant run -> no two_tenant key, but the section exists
        assert!(section.get("two_tenant").is_none());
    }

    #[test]
    fn two_tenant_json_round_trips() {
        let tt = TwoTenantResult {
            requests_a: 1920,
            requests_b: 192,
            capacity_req_s: 800.0,
            deadline_us: 100_000,
            quota_a_req_s: 200.0,
            solo_goodput_req_s: 200.0,
            solo_p50_us: 4_000.0,
            solo_p99_us: 9_000.0,
            contended_goodput_req_s: 196.0,
            contended_p50_us: 4_200.0,
            contended_p99_us: 9_800.0,
            goodput_ratio: 0.98,
            p99_ratio: 9_800.0 / 9_000.0,
            done_a: 180,
            shed_quota_a: 1600,
            shed_quota_b: 0,
            exactly_once: true,
        };
        // a two_tenant result alone is enough to emit the section
        let j = to_json(&[], &[], &[], None, &[], Some(&tt));
        let back = crate::util::json::parse(&j.to_string()).unwrap();
        let section = back.get("overload").unwrap();
        assert_eq!(section.get("traces").and_then(Json::as_arr)
                       .map(<[Json]>::len),
                   Some(0));
        let t = section.get("two_tenant").unwrap();
        assert_eq!(t.get("requests_a").and_then(Json::as_i64),
                   Some(1920));
        assert_eq!(t.get("shed_quota_a").and_then(Json::as_i64),
                   Some(1600));
        assert_eq!(t.get("shed_quota_b").and_then(Json::as_i64), Some(0));
        assert_eq!(t.get("exactly_once").and_then(Json::as_bool),
                   Some(true));
        let g = t.get("goodput_ratio").and_then(Json::as_f64).unwrap();
        assert!((g - 0.98).abs() < 1e-9);
        let p = t.get("p99_ratio").and_then(Json::as_f64).unwrap();
        assert!(p > 1.0 && p < 1.2);
    }

    #[test]
    fn layout_serve_sigs_pair_nchw_with_nhwc() {
        let sigs = layout_serve_sigs();
        let nchw: Vec<&String> = sigs.iter().filter(|(l, _)| *l == "nchw")
            .map(|(_, s)| s).collect();
        let nhwc: Vec<String> = sigs.iter().filter(|(l, _)| *l == "nhwc")
            .map(|(_, s)| s.clone()).collect();
        assert_eq!(nchw.len(), nhwc.len());
        for s in nchw {
            let twin = format!("{s}-nhwc");
            assert!(nhwc.contains(&twin), "missing nhwc twin for {s}");
        }
        // the dedicated depthwise solver rides the layout sweep too
        assert!(sigs.iter().any(|(_, s)| s.contains("-depthwise-")));
    }

    #[test]
    fn dtype_serve_sigs_pair_f32_with_bf16() {
        let sigs = dtype_serve_sigs();
        let f32s: Vec<&String> = sigs.iter().filter(|(d, _)| *d == "f32")
            .map(|(_, s)| s).collect();
        let bf16s: Vec<String> = sigs.iter().filter(|(d, _)| *d == "bf16")
            .map(|(_, s)| s.clone()).collect();
        assert_eq!(f32s.len(), bf16s.len());
        for f in f32s {
            let twin = f.replace("-f32", "-bf16");
            assert!(bf16s.contains(&twin), "missing bf16 twin for {f}");
        }
    }
}
