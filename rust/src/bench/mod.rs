//! Bench harness (criterion stand-in, DESIGN.md §Substitutions #5):
//! warmup + timed iterations with robust statistics, plus the table
//! printer the figure-reproduction benches share. The serve-bench sweep
//! (worker count × batch size × arrival rate) lives in [`serve`]; the
//! naive-vs-blocked GEMM + workspace-arena sweep lives in [`kernels`].

pub mod kernels;
pub mod serve;

use std::time::Instant;

use crate::metrics::TimingStats;

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub timed_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup_iters: 2, timed_iters: 5 }
    }
}

impl BenchConfig {
    pub fn quick() -> Self {
        Self { warmup_iters: 1, timed_iters: 3 }
    }

    /// From env (MIOPEN_RS_BENCH_ITERS) for CI-speed control.
    pub fn from_env() -> Self {
        let iters = std::env::var("MIOPEN_RS_BENCH_ITERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(5);
        Self { warmup_iters: 2, timed_iters: iters }
    }
}

/// Time a closure: returns stats over `timed_iters` runs (µs).
pub fn time_fn(cfg: &BenchConfig, mut f: impl FnMut()) -> TimingStats {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut stats = TimingStats::new();
    for _ in 0..cfg.timed_iters {
        let t = Instant::now();
        f();
        stats.record(t.elapsed().as_secs_f64() * 1e6);
    }
    stats
}

/// Fixed-width table printer for the figure benches.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>()
                                 + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Shared CLI filter for bench binaries: `cargo bench -- <filter>` runs
/// only sections whose name contains the filter.
pub fn section_enabled(name: &str) -> bool {
    let args: Vec<String> = std::env::args().skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    args.is_empty() || args.iter().any(|a| name.contains(a.as_str()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_iters() {
        let cfg = BenchConfig { warmup_iters: 1, timed_iters: 4 };
        let mut calls = 0;
        let stats = time_fn(&cfg, || calls += 1);
        assert_eq!(calls, 5);
        assert_eq!(stats.count(), 4);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "us"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["longer".into(), "12.5".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert_eq!(lines[2].len(), lines[3].len());
    }
}
