//! Problem-configuration sets — the Rust mirror of
//! `python/compile/configs.py` plus the artifact enumeration of
//! `python/compile/aot.py`.
//!
//! The Python side is the source of truth when artifacts are AOT'd
//! (`make artifacts` writes `manifest.json`). This module regenerates the
//! *same* artifact set in-process so the interp backend can serve every
//! signature hermetically — no Python, no PJRT, no files on disk. The two
//! enumerations must stay in sync; `python/tests/test_aot.py` and the
//! integration suites cross-check signatures from both sides.

use crate::manifest::{Artifact, TensorSpec};
use crate::types::{algo, DType, Layout, ProblemSig, TuneTag};

/// Mirror of `configs.ConvConfig`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvConfig {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub k: usize,
    pub r: usize,
    pub s: usize,
    pub u: usize,
    pub v: usize,
    pub p: usize,
    pub q: usize,
    pub l: usize,
    pub j: usize,
    pub g: usize,
}

/// Dense stride-1 unpadded config (the dataclass defaults).
pub const fn cc(n: usize, c: usize, h: usize, w: usize, k: usize, r: usize,
                s: usize) -> ConvConfig {
    ConvConfig { n, c, h, w, k, r, s, u: 1, v: 1, p: 0, q: 0, l: 1, j: 1, g: 1 }
}

impl ConvConfig {
    pub fn sig_params(&self) -> String {
        format!(
            "n{}c{}h{}w{}k{}r{}s{}u{}v{}p{}q{}l{}j{}g{}",
            self.n, self.c, self.h, self.w, self.k, self.r, self.s, self.u,
            self.v, self.p, self.q, self.l, self.j, self.g
        )
    }

    pub fn out_hw(&self) -> (usize, usize) {
        let er = (self.r - 1) * self.l + 1;
        let es = (self.s - 1) * self.j + 1;
        let ho = (self.h + 2 * self.p - er) / self.u + 1;
        let wo = (self.w + 2 * self.q - es) / self.v + 1;
        (ho, wo)
    }

    /// Figure 6 x-axis label.
    pub fn label(&self) -> String {
        format!("{}-{}-{}-{}-{}-{}-{}-{}",
                self.r, self.s, self.c, self.h, self.w, self.k, self.p, self.q)
    }

    fn param_pairs(&self) -> Vec<(&'static str, i64)> {
        vec![
            ("n", self.n as i64), ("c", self.c as i64), ("h", self.h as i64),
            ("w", self.w as i64), ("k", self.k as i64), ("r", self.r as i64),
            ("s", self.s as i64), ("u", self.u as i64), ("v", self.v as i64),
            ("p", self.p as i64), ("q", self.q as i64), ("l", self.l as i64),
            ("j", self.j as i64), ("g", self.g as i64),
        ]
    }

    /// The equivalent [`ProblemSig`] (for solver workspace/applicability
    /// queries during artifact emission). NCHW; see [`Self::problem_sig_l`]
    /// for the layout-explicit form.
    pub fn problem_sig(&self, direction: &str, dtype: DType) -> ProblemSig {
        self.problem_sig_l(direction, dtype, Layout::Nchw)
    }

    pub fn problem_sig_l(&self, direction: &str, dtype: DType,
                         layout: Layout) -> ProblemSig {
        ProblemSig {
            direction: direction.to_string(),
            n: self.n, c: self.c, h: self.h, w: self.w, k: self.k,
            r: self.r, s: self.s, u: self.u, v: self.v, p: self.p,
            q: self.q, l: self.l, j: self.j, g: self.g, dtype, layout,
        }
    }
}

/// Mirror of `configs.RnnConfig`.
#[derive(Debug, Clone, Copy)]
pub struct RnnConfig {
    pub cell: &'static str,
    pub t: usize,
    pub b: usize,
    pub x: usize,
    pub hid: usize,
    pub act: &'static str,
}

impl RnnConfig {
    pub fn sig_params(&self) -> String {
        format!("t{}b{}x{}h{}", self.t, self.b, self.x, self.hid)
    }
}

// -- Figure 6 convolution configs (configs.py FIG6_1X1 / FIG6_NON1X1) --------

pub fn fig6_1x1() -> Vec<ConvConfig> {
    vec![
        cc(4, 16, 28, 28, 16, 1, 1),
        cc(4, 48, 28, 28, 16, 1, 1),
        cc(4, 120, 14, 14, 32, 1, 1),
        cc(4, 128, 14, 14, 32, 1, 1),
        cc(4, 208, 7, 7, 64, 1, 1),
        ConvConfig { u: 2, v: 2, ..cc(4, 32, 28, 28, 64, 1, 1) },
        cc(4, 64, 14, 14, 96, 1, 1),
        cc(4, 96, 7, 7, 128, 1, 1),
    ]
}

pub fn fig6_non1x1() -> Vec<ConvConfig> {
    vec![
        ConvConfig { p: 1, q: 1, ..cc(4, 16, 28, 28, 32, 3, 3) },
        ConvConfig { p: 1, q: 1, ..cc(4, 32, 28, 28, 48, 3, 3) },
        ConvConfig { p: 1, q: 1, ..cc(4, 28, 14, 14, 52, 3, 3) },
        ConvConfig { p: 1, q: 1, ..cc(4, 40, 14, 14, 80, 3, 3) },
        ConvConfig { p: 2, q: 2, ..cc(4, 4, 28, 28, 8, 5, 5) },
        ConvConfig { p: 2, q: 2, ..cc(4, 8, 14, 14, 16, 5, 5) },
        ConvConfig { u: 2, v: 2, p: 3, q: 3, ..cc(4, 3, 32, 32, 16, 7, 7) },
        ConvConfig { u: 2, v: 2, p: 1, q: 1, ..cc(4, 16, 14, 14, 48, 3, 3) },
    ]
}

pub fn fig7a() -> Vec<ConvConfig> {
    let mut out: Vec<ConvConfig> = [4usize, 8, 16, 32, 64, 96]
        .iter()
        .map(|&k| ConvConfig { p: 1, q: 1, ..cc(4, 16, 14, 14, k, 3, 3) })
        .collect();
    out.push(cc(4, 16, 28, 28, 8, 1, 1));
    out.push(cc(4, 16, 28, 28, 32, 1, 1));
    out
}

/// (C, H, W) with N fixed at 4.
pub const FIG7B: [(usize, usize, usize); 8] = [
    (4, 7, 7), (8, 7, 7), (16, 14, 14), (8, 28, 28),
    (16, 28, 28), (32, 28, 28), (16, 56, 56), (32, 56, 56),
];

pub fn grouped_configs() -> Vec<ConvConfig> {
    vec![
        ConvConfig { p: 1, q: 1, g: 32, ..cc(4, 32, 14, 14, 32, 3, 3) },
        ConvConfig { p: 1, q: 1, g: 2, ..cc(4, 16, 14, 14, 32, 3, 3) },
        ConvConfig { u: 2, v: 2, p: 1, q: 1, g: 8, ..cc(2, 8, 28, 28, 8, 3, 3) },
    ]
}

pub fn int8_configs() -> Vec<ConvConfig> {
    vec![
        ConvConfig { p: 1, q: 1, ..cc(4, 16, 14, 14, 32, 3, 3) },
        cc(4, 16, 28, 28, 16, 1, 1),
    ]
}

pub fn tune_configs() -> Vec<ConvConfig> {
    vec![
        ConvConfig { p: 1, q: 1, ..cc(4, 16, 28, 28, 32, 3, 3) },
        cc(4, 64, 14, 14, 64, 1, 1),
    ]
}

/// The configs the embedded read-only db is generated from (see
/// `db::embed`): every conv family the builtin manifest serves, so a
/// binary on an unwritable filesystem still has a ranking for each.
pub fn embedded_db_configs() -> Vec<ConvConfig> {
    let mut out = fig6_1x1();
    out.extend(fig6_non1x1());
    out.extend(grouped_configs());
    out.extend(tune_configs());
    out.dedup();
    out
}

pub const DIRECT_BLOCK_K: [usize; 4] = [4, 8, 16, 32];

/// AOT'd blocked-GEMM tile-grid indices (`-gt{i}`) — one artifact per
/// entry of the engine's `MC×NC` grid, so the tuning session can race
/// every tile config (mirrors `configs.GEMM_TILE_GRID` in python).
pub fn gemm_tile_grid() -> Vec<usize> {
    (0..crate::runtime::interp::gemm::TILE_CONFIGS.len()).collect()
}

/// AOT'd winograd transform-domain parallelism variants (`-wt{n}`) —
/// the solver's grid itself, so a new grid point cannot be silently
/// filtered by the tuning session for lack of an artifact.
pub const WINOGRAD_TILE_THREADS: [usize; 3] =
    crate::solvers::WinogradSolver::THREAD_GRID;

pub fn rnn_configs() -> Vec<RnnConfig> {
    vec![
        RnnConfig { cell: "lstm", t: 16, b: 8, x: 32, hid: 32, act: "tanh" },
        RnnConfig { cell: "lstm", t: 32, b: 8, x: 64, hid: 64, act: "tanh" },
        RnnConfig { cell: "gru", t: 16, b: 8, x: 32, hid: 32, act: "tanh" },
        RnnConfig { cell: "vanilla", t: 16, b: 8, x: 32, hid: 32, act: "relu" },
    ]
}

pub const RNN_ABLATION_T: [usize; 4] = [4, 8, 16, 32];

pub const BN_SHAPES: [(usize, usize, usize, usize); 2] =
    [(4, 16, 14, 14), (4, 32, 28, 28)];

/// (shape, window, stride, pad, mode)
type PoolCfg = ((usize, usize, usize, usize), (usize, usize), (usize, usize),
                (usize, usize), &'static str);
pub const POOL_SHAPES: [PoolCfg; 3] = [
    ((4, 16, 28, 28), (2, 2), (2, 2), (0, 0), "max"),
    ((4, 16, 28, 28), (2, 2), (2, 2), (0, 0), "avg"),
    ((4, 8, 14, 14), (3, 3), (2, 2), (1, 1), "max"),
];

pub const SOFTMAX_SHAPES: [(usize, usize, usize, usize); 2] =
    [(4, 10, 1, 1), (4, 16, 14, 14)];
pub const ACT_SHAPES: [(usize, usize, usize, usize); 1] = [(4, 16, 28, 28)];
pub const ACT_MODES: [&str; 4] = ["relu", "leaky_relu", "tanh", "sigmoid"];
pub const LRN_SHAPES: [(usize, usize, usize, usize); 1] = [(4, 16, 14, 14)];

/// Mirror of `configs.CNN` (the E2E tiny-CNN used by train/serve).
pub mod cnn {
    pub const IMAGE: usize = 16;
    pub const CHANNELS: usize = 3;
    pub const CLASSES: usize = 3;
    pub const C1: usize = 8;
    pub const C2: usize = 16;
    pub const HIDDEN_HW: usize = 4;
    pub const BATCH: usize = 16;
    pub const LR: f32 = 0.05;
    /// Flattened feature size after the two conv/pool stages.
    pub const FEAT: usize = C2 * HIDDEN_HW * HIDDEN_HW;
}

// ---------------------------------------------------------------------------
// Artifact enumeration (mirror of aot.py's emit_* functions)
// ---------------------------------------------------------------------------

fn sp(shape: &[usize], dtype: DType) -> TensorSpec {
    TensorSpec { shape: shape.to_vec(), dtype }
}

fn f32s(shape: &[usize]) -> TensorSpec {
    sp(shape, DType::F32)
}

/// Applicable forward algorithms (mirrors aot.fwd_algos AND the solver
/// registry's applicability — the three must agree; the
/// `builtin_matches_solver_applicability` test locks the contract).
pub fn fwd_algos(c: &ConvConfig) -> Vec<&'static str> {
    let mut algos = vec![algo::GEMM, algo::DIRECT, algo::IMPLICIT];
    if c.g == c.c && c.g > 1 {
        algos.insert(0, algo::DEPTHWISE);
    }
    if (c.r, c.s) == (3, 3) && (c.u, c.v) == (1, 1) && (c.l, c.j) == (1, 1)
        && c.g == 1 {
        algos.push(algo::WINOGRAD);
    }
    if c.r.max(c.s) >= 5 && (c.l, c.j) == (1, 1) && c.g == 1 {
        algos.push(algo::FFT);
    }
    algos
}

pub fn bwd_algos(c: &ConvConfig) -> Vec<&'static str> {
    let mut algos = vec![algo::GEMM, algo::DIRECT];
    if (c.r, c.s) == (3, 3) && (c.u, c.v) == (1, 1) && (c.l, c.j) == (1, 1)
        && c.g == 1 && c.p <= 2 && c.q <= 2 {
        algos.push(algo::WINOGRAD);
    }
    algos
}

fn conv_sig(direction: &str, algo_name: &str, c: &ConvConfig, dtype: &str,
            tag: Option<TuneTag>) -> String {
    conv_sig_l(direction, algo_name, c, dtype, Layout::Nchw, tag)
}

fn conv_sig_l(direction: &str, algo_name: &str, c: &ConvConfig, dtype: &str,
              layout: Layout, tag: Option<TuneTag>) -> String {
    let l = if layout == Layout::Nhwc { "-nhwc" } else { "" };
    let t = tag.map(TuneTag::suffix).unwrap_or_default();
    format!("conv_{direction}-{algo_name}-{}-{dtype}{l}{t}", c.sig_params())
}

fn conv_specs(direction: &str, c: &ConvConfig, dtype: DType, layout: Layout)
    -> (Vec<TensorSpec>, Vec<TensorSpec>) {
    let (ho, wo) = c.out_hw();
    // NHWC artifacts advertise channels-last buffers: the spec shapes
    // are the physical axis order, while sig params stay logical NCHW.
    let (xs, ws, ys) = match layout {
        Layout::Nchw => ([c.n, c.c, c.h, c.w], [c.k, c.c / c.g, c.r, c.s],
                         [c.n, c.k, ho, wo]),
        Layout::Nhwc => ([c.n, c.h, c.w, c.c], [c.k, c.r, c.s, c.c / c.g],
                         [c.n, ho, wo, c.k]),
    };
    match direction {
        "fwd" => (vec![sp(&xs, dtype), sp(&ws, dtype)], vec![sp(&ys, dtype)]),
        "bwd" => (vec![sp(&ys, dtype), sp(&ws, dtype)], vec![sp(&xs, dtype)]),
        _ => (vec![sp(&ys, dtype), sp(&xs, dtype)], vec![sp(&ws, dtype)]),
    }
}

fn conv_artifact(direction: &str, algo_name: &str, c: &ConvConfig,
                 dtype: DType, tag: Option<TuneTag>) -> Artifact {
    conv_artifact_l(direction, algo_name, c, dtype, Layout::Nchw, tag)
}

fn conv_artifact_l(direction: &str, algo_name: &str, c: &ConvConfig,
                   dtype: DType, layout: Layout, tag: Option<TuneTag>)
    -> Artifact {
    let (inputs, outputs) = conv_specs(direction, c, dtype, layout);
    // one workspace formula per algorithm, shared with the find step
    let ws = crate::solvers::workspace_for(
        algo_name, &c.problem_sig_l(direction, dtype, layout));
    let mut art = Artifact::synthetic(
        &conv_sig_l(direction, algo_name, c, dtype.name(), layout, tag),
        "conv", algo_name, direction, inputs, outputs)
        .with_params(&c.param_pairs())
        .with_label(&c.label())
        .with_workspace(ws);
    match tag {
        Some(TuneTag::BlockK(b)) => {
            art = art.with_tuning(&[(crate::solvers::BLOCK_K_PARAM,
                                     b as i64)]);
        }
        Some(TuneTag::WinoThreads(t)) => {
            art = art.with_tuning(&[(crate::solvers::WINO_THREADS_PARAM,
                                     t as i64)]);
        }
        Some(TuneTag::GemmTile(i)) => {
            art = art.with_tuning(&[(crate::solvers::GEMM_TILE_PARAM,
                                     i as i64)]);
        }
        None => {}
    }
    art
}

fn emit_conv_family(out: &mut Vec<Artifact>) {
    // Figure 6 panels: fwd -> a/b, bwd -> c/d, wrw -> e/f.
    for (set, one_by_one) in [(fig6_1x1(), true), (fig6_non1x1(), false)] {
        for c in &set {
            for (direction, panels) in
                [("fwd", ("a", "b")), ("bwd", ("c", "d")), ("wrw", ("e", "f"))] {
                let panel = if one_by_one { panels.0 } else { panels.1 };
                let algos = match direction {
                    "fwd" => fwd_algos(c),
                    "bwd" => bwd_algos(c),
                    _ => vec![algo::GEMM, algo::DIRECT],
                };
                for a in algos {
                    out.push(conv_artifact(direction, a, c, DType::F32, None)
                        .with_tag(&format!("fig6{panel}")));
                }
            }
        }
    }
    // Mixed-precision set: bf16 is a first-class execution dtype (2-byte
    // storage end-to-end, f32 accumulate, one rounding at the store —
    // docs/NUMERICS.md), so the artifact surface mirrors the f32 zoo on
    // exemplar configs: every applicable fwd algorithm (winograd and fft
    // included), bwd/wrw for the universal gemm/direct pair, and an f16
    // slice of the same fwd surface.
    let mp_fwd: Vec<ConvConfig> = fig6_1x1()
        .into_iter()
        .take(2)
        .chain(fig6_non1x1().into_iter().take(2)) // 3×3: winograd rides
        .chain(fig6_non1x1().into_iter().skip(4).take(1)) // 5×5: fft rides
        .chain(tune_configs().into_iter().skip(1)) // tuned 1×1's default
        .collect();
    for c in &mp_fwd {
        for a in fwd_algos(c) {
            out.push(conv_artifact("fwd", a, c, DType::Bf16, None)
                .with_tag("bf16"));
        }
    }
    let mp_bwd = fig6_non1x1()[0]; // 3×3 p1: winograd bwd applies too
    for a in bwd_algos(&mp_bwd) {
        out.push(conv_artifact("bwd", a, &mp_bwd, DType::Bf16, None)
            .with_tag("bf16"));
    }
    for a in [algo::GEMM, algo::DIRECT] {
        out.push(conv_artifact("wrw", a, &mp_bwd, DType::Bf16, None)
            .with_tag("bf16"));
    }
    for c in [fig6_1x1()[0], fig6_non1x1()[0]] {
        for a in fwd_algos(&c) {
            out.push(conv_artifact("fwd", a, &c, DType::F16, None)
                .with_tag("f16"));
        }
    }
    // grouped (direct fallback); depthwise-shaped entries (g == c) also
    // get the dedicated depthwise solver's artifact in both layouts.
    for c in &grouped_configs() {
        out.push(conv_artifact("fwd", algo::DIRECT, c, DType::F32, None)
            .with_tag("grouped"));
        if c.g == c.c && c.g > 1 {
            out.push(conv_artifact("fwd", algo::DEPTHWISE, c, DType::F32,
                                   None)
                .with_tag("depthwise"));
            out.push(conv_artifact_l("fwd", algo::DEPTHWISE, c, DType::F32,
                                     Layout::Nhwc, None)
                .with_tag("depthwise-nhwc"));
        }
    }
    // depthwise tuned variants: the solver's channel-block grid on the
    // first depthwise exemplar, per layout (`-bk` reuses the direct
    // solver's block_k key — the tuning grammar stays closed).
    {
        let dw = grouped_configs()[0];
        debug_assert!(dw.g == dw.c && dw.g > 1);
        for bk in crate::solvers::DepthwiseSolver::BLOCK_GRID {
            if bk > dw.c.max(4) {
                continue;
            }
            for layout in [Layout::Nchw, Layout::Nhwc] {
                out.push(conv_artifact_l("fwd", algo::DEPTHWISE, &dw,
                                         DType::F32, layout,
                                         Some(TuneTag::BlockK(bk)))
                    .with_tag("tune-depthwise"));
            }
        }
    }
    // NHWC exemplar set: the full applicable fwd zoo on one config per
    // filter family (1×1 gemm-friendly, 3×3 winograd-able, 5×5
    // fft-able), bwd/wrw via the transpose-at-boundary direct path, a
    // bf16 slice, and tuned `-bk`/`-gt` variants so per-layout tuning
    // sessions resolve NHWC artifacts.
    for c in [fig6_1x1()[0], fig6_non1x1()[0], fig6_non1x1()[4]] {
        for a in fwd_algos(&c) {
            out.push(conv_artifact_l("fwd", a, &c, DType::F32, Layout::Nhwc,
                                     None)
                .with_tag("nhwc"));
        }
    }
    let nhwc_bwd = fig6_non1x1()[0];
    for direction in ["bwd", "wrw"] {
        out.push(conv_artifact_l(direction, algo::DIRECT, &nhwc_bwd,
                                 DType::F32, Layout::Nhwc, None)
            .with_tag("nhwc"));
    }
    for a in [algo::DIRECT, algo::GEMM] {
        out.push(conv_artifact_l("fwd", a, &fig6_non1x1()[0], DType::Bf16,
                                 Layout::Nhwc, None)
            .with_tag("nhwc-bf16"));
    }
    {
        let tc = tune_configs()[0];
        for bk in DIRECT_BLOCK_K {
            out.push(conv_artifact_l("fwd", algo::DIRECT, &tc, DType::F32,
                                     Layout::Nhwc, Some(TuneTag::BlockK(bk)))
                .with_tag("tune-nhwc"));
        }
        for gt in gemm_tile_grid() {
            out.push(conv_artifact_l("fwd", algo::GEMM, &tc, DType::F32,
                                     Layout::Nhwc, Some(TuneTag::GemmTile(gt)))
                .with_tag("tune-nhwc"));
        }
    }
    // int8 inference: i8 inputs, exact f32 accumulation and output.
    for c in &int8_configs() {
        let xs = [c.n, c.c, c.h, c.w];
        let ws = [c.k, c.c, c.r, c.s];
        let (ho, wo) = c.out_hw();
        out.push(
            Artifact::synthetic(
                &format!("conv_fwd-direct-{}-i8", c.sig_params()), "conv",
                algo::DIRECT, "fwd",
                vec![sp(&xs, DType::I8), sp(&ws, DType::I8)],
                vec![f32s(&[c.n, c.k, ho, wo])])
            .with_dtype(DType::I8)
            .with_params(&c.param_pairs())
            .with_label(&c.label())
            .with_tag("int8"),
        );
    }
    // tuning variants: direct block_k tiles, winograd transform-domain
    // parallelism (only where the winograd solver applies), and the
    // blocked-GEMM MC×NC tile grid — emitted per dtype, because tuned
    // `-bk`/`-wt`/`-gt` variants resolve through per-dtype perf-db keys
    // (a bf16 tuning session must never be served an f32 artifact).
    for c in &tune_configs() {
        for dtype in [DType::F32, DType::Bf16] {
            let dtag = if dtype == DType::F32 { "tune" } else { "tune-bf16" };
            for bk in DIRECT_BLOCK_K {
                out.push(conv_artifact("fwd", algo::DIRECT, c, dtype,
                                       Some(TuneTag::BlockK(bk)))
                    .with_tag(dtag));
            }
            if fwd_algos(c).contains(&algo::WINOGRAD) {
                for wt in WINOGRAD_TILE_THREADS {
                    out.push(conv_artifact("fwd", algo::WINOGRAD, c, dtype,
                                           Some(TuneTag::WinoThreads(wt)))
                        .with_tag(if dtype == DType::F32 { "tune-wino" }
                                  else { "tune-bf16" }));
                }
            }
            for gt in gemm_tile_grid() {
                out.push(conv_artifact("fwd", algo::GEMM, c, dtype,
                                       Some(TuneTag::GemmTile(gt)))
                    .with_tag(if dtype == DType::F32 { "tune-gemm" }
                              else { "tune-bf16" }));
            }
        }
    }
}

/// The conv algorithm a relu CBA fusion plan over this config and dtype
/// would select — decided by the *same* metadata graph the fusion API
/// traverses, so the recorded `conv_algo` and the mdgraph can never
/// disagree. Half-precision plans go through Table II's restrictions
/// (CBA only via the direct 1×1 kernel — the winograd rows are f32).
fn cba_conv_algo(c: &ConvConfig, dtype: DType) -> &'static str {
    use crate::descriptors::ActivationMode;
    use crate::fusion::mdgraph::{MdGraph, OpKind, PlanAttrs};
    let attrs = PlanAttrs {
        dtype,
        filter: Some((c.r, c.s)),
        stride: Some((c.u, c.v)),
        pad: Some((c.p, c.q)),
        channels: Some(c.c),
        activation: Some(ActivationMode::Relu),
    };
    MdGraph::standard()
        .accept(&[OpKind::Conv, OpKind::Bias, OpKind::Activation], &attrs)
        .map(|m| m.conv_algo)
        .unwrap_or(algo::DIRECT)
}

fn emit_fusion_family(out: &mut Vec<Artifact>) {
    // Figure 7a: CBA fused vs {conv, bias, act} separate.
    for c in &fig7a() {
        let xs = [c.n, c.c, c.h, c.w];
        let ws = [c.k, c.c, c.r, c.s];
        let (ho, wo) = c.out_hw();
        let ys = [c.n, c.k, ho, wo];
        out.push(
            Artifact::synthetic(
                &format!("cba-relu-{}-f32", c.sig_params()), "fusion", "cba",
                "fwd",
                vec![f32s(&xs), f32s(&ws), f32s(&[c.k])], vec![f32s(&ys)])
            .with_params(&c.param_pairs())
            .with_str_param("conv_algo", cba_conv_algo(c, DType::F32))
            .with_label(&c.label())
            .with_tag("fig7a"),
        );
        out.push(conv_artifact("fwd", algo::DIRECT, c, DType::F32, None)
            .with_tag("fig7a-sep"));
        out.push(
            Artifact::synthetic(
                &format!("bias-{}x{}x{ho}x{wo}-f32", c.n, c.k), "tensor_op",
                "bias", "fwd", vec![f32s(&ys), f32s(&[c.k])], vec![f32s(&ys)])
            .with_params(&c.param_pairs())
            .with_tag("fig7a-sep"),
        );
        out.push(
            Artifact::synthetic(
                &format!("act-relu-{}x{}x{ho}x{wo}-f32", c.n, c.k),
                "activation", "relu", "fwd", vec![f32s(&ys)], vec![f32s(&ys)])
            .with_params(&c.param_pairs())
            .with_tag("fig7a-sep"),
        );
    }

    // Figure 7b: BN+A fused vs {bn_infer, act} separate (N fixed at 4).
    let n = 4usize;
    for (c, h, w) in FIG7B {
        let shape = [n, c, h, w];
        let pv: Vec<(&str, i64)> = vec![
            ("n", n as i64), ("c", c as i64), ("h", h as i64), ("w", w as i64),
        ];
        let label = format!("{c}x{h}x{w}");
        out.push(
            Artifact::synthetic(
                &format!("bna-relu-n{n}c{c}h{h}w{w}-f32"), "fusion", "bna",
                "fwd",
                vec![f32s(&shape), f32s(&[c]), f32s(&[c]), f32s(&[c]),
                     f32s(&[c])],
                vec![f32s(&shape)])
            .with_params(&pv)
            .with_label(&label)
            .with_tag("fig7b"),
        );
        out.push(
            Artifact::synthetic(
                &format!("bn_infer-spatial-n{n}c{c}h{h}w{w}-f32"), "batchnorm",
                "spatial_infer", "fwd",
                vec![f32s(&shape), f32s(&[c]), f32s(&[c]), f32s(&[c]),
                     f32s(&[c])],
                vec![f32s(&shape)])
            .with_params(&pv)
            .with_tag("fig7b-sep"),
        );
        out.push(
            Artifact::synthetic(
                &format!("act-relu-{n}x{c}x{h}x{w}-f32"), "activation", "relu",
                "fwd", vec![f32s(&shape)], vec![f32s(&shape)])
            .with_params(&pv)
            .with_tag("fig7b-sep"),
        );
    }

    // CBNA exemplars (Tables I/II row 1), one per stride. CBNA rows are
    // direct-only in the metadata graph.
    for c in [
        ConvConfig { p: 1, q: 1, ..cc(2, 8, 14, 14, 8, 3, 3) },
        ConvConfig { u: 2, v: 2, p: 1, q: 1, ..cc(2, 8, 14, 14, 8, 3, 3) },
    ] {
        let xs = [c.n, c.c, c.h, c.w];
        let ws = [c.k, c.c, c.r, c.s];
        let (ho, wo) = c.out_hw();
        out.push(
            Artifact::synthetic(
                &format!("cbna-relu-{}-f32", c.sig_params()), "fusion", "cbna",
                "fwd",
                vec![f32s(&xs), f32s(&ws), f32s(&[c.k]), f32s(&[c.k]),
                     f32s(&[c.k]), f32s(&[c.k]), f32s(&[c.k])],
                vec![f32s(&[c.n, c.k, ho, wo])])
            .with_params(&c.param_pairs())
            .with_str_param("conv_algo", algo::DIRECT)
            .with_tag("fusion-exec"),
        );
    }

    // Table II executable half-precision exemplars: the bf16 fusion
    // rules are enforced by plans that actually run (2-byte storage,
    // f32 accumulate inside the fused kernel), not just by graph
    // pruning. Table II admits exactly CBA-direct-1×1 and CBNA-direct;
    // a bf16 winograd CBA has no artifact because the mdgraph rejects
    // the plan outright (integration_fusion pins both sides).
    {
        let c = cc(4, 16, 28, 28, 32, 1, 1); // CBA direct 1×1 row
        debug_assert_eq!(cba_conv_algo(&c, DType::Bf16), algo::DIRECT);
        let xs = [c.n, c.c, c.h, c.w];
        let ws = [c.k, c.c, c.r, c.s];
        let (ho, wo) = c.out_hw();
        let b16 = |shape: &[usize]| sp(shape, DType::Bf16);
        out.push(
            Artifact::synthetic(
                &format!("cba-relu-{}-bf16", c.sig_params()), "fusion",
                "cba", "fwd",
                vec![b16(&xs), b16(&ws), b16(&[c.k])],
                vec![b16(&[c.n, c.k, ho, wo])])
            .with_params(&c.param_pairs())
            .with_str_param("conv_algo", cba_conv_algo(&c, DType::Bf16))
            .with_label(&c.label())
            .with_tag("fusion-bf16"),
        );
        let cb = ConvConfig { p: 1, q: 1, ..cc(2, 8, 14, 14, 8, 3, 3) };
        let xsb = [cb.n, cb.c, cb.h, cb.w];
        let wsb = [cb.k, cb.c, cb.r, cb.s];
        let (hob, wob) = cb.out_hw();
        out.push(
            Artifact::synthetic(
                &format!("cbna-relu-{}-bf16", cb.sig_params()), "fusion",
                "cbna", "fwd",
                vec![b16(&xsb), b16(&wsb), b16(&[cb.k]), b16(&[cb.k]),
                     b16(&[cb.k]), b16(&[cb.k]), b16(&[cb.k])],
                vec![b16(&[cb.n, cb.k, hob, wob])])
            .with_params(&cb.param_pairs())
            .with_str_param("conv_algo", algo::DIRECT)
            .with_tag("fusion-bf16"),
        );
    }

    // NHWC CBA exemplar: the direct 1×1 row is the one CBA family the
    // layout axis admits (winograd rows are NCHW-only in the mdgraph);
    // channels-last specs, `-nhwc` sig tail, executed by the interp
    // backend's NHWC fused path.
    {
        let c = cc(4, 16, 28, 28, 32, 1, 1);
        debug_assert_eq!(cba_conv_algo(&c, DType::F32), algo::DIRECT);
        let (ho, wo) = c.out_hw();
        out.push(
            Artifact::synthetic(
                &format!("cba-relu-{}-f32-nhwc", c.sig_params()), "fusion",
                "cba", "fwd",
                vec![f32s(&[c.n, c.h, c.w, c.c]),
                     f32s(&[c.k, c.r, c.s, c.c]), f32s(&[c.k])],
                vec![f32s(&[c.n, ho, wo, c.k])])
            .with_params(&c.param_pairs())
            .with_str_param("conv_algo", algo::DIRECT)
            .with_label(&c.label())
            .with_tag("fusion-nhwc"),
        );
    }

    // Winograd CBA exemplar (Table I winograd rows): 3x3/s1, c >= 18 and
    // even, relu — the mdgraph selects winograd for this plan and the
    // interp backend executes the F(2,3) pipeline inside the fused
    // kernel. Separate-op artifacts ride along so the integration suite
    // can check fused-vs-separate parity per algorithm.
    {
        let c = ConvConfig { p: 1, q: 1, ..cc(4, 32, 14, 14, 8, 3, 3) };
        debug_assert_eq!(cba_conv_algo(&c, DType::F32), algo::WINOGRAD);
        let xs = [c.n, c.c, c.h, c.w];
        let ws = [c.k, c.c, c.r, c.s];
        let (ho, wo) = c.out_hw();
        let ys = [c.n, c.k, ho, wo];
        out.push(
            Artifact::synthetic(
                &format!("cba-relu-{}-f32", c.sig_params()), "fusion", "cba",
                "fwd",
                vec![f32s(&xs), f32s(&ws), f32s(&[c.k])], vec![f32s(&ys)])
            .with_params(&c.param_pairs())
            .with_str_param("conv_algo", cba_conv_algo(&c, DType::F32))
            .with_label(&c.label())
            .with_tag("fusion-wino"),
        );
        for a in [algo::DIRECT, algo::WINOGRAD] {
            out.push(conv_artifact("fwd", a, &c, DType::F32, None)
                .with_tag("fusion-wino-sep"));
        }
        out.push(
            Artifact::synthetic(
                &format!("bias-{}x{}x{ho}x{wo}-f32", c.n, c.k), "tensor_op",
                "bias", "fwd", vec![f32s(&ys), f32s(&[c.k])], vec![f32s(&ys)])
            .with_params(&c.param_pairs())
            .with_tag("fusion-wino-sep"),
        );
        out.push(
            Artifact::synthetic(
                &format!("act-relu-{}x{}x{ho}x{wo}-f32", c.n, c.k),
                "activation", "relu", "fwd", vec![f32s(&ys)], vec![f32s(&ys)])
            .with_params(&c.param_pairs())
            .with_tag("fusion-wino-sep"),
        );
    }
}

fn emit_primitives(out: &mut Vec<Artifact>) {
    for (n, c, h, w) in BN_SHAPES {
        let shape = [n, c, h, w];
        let base = format!("n{n}c{c}h{h}w{w}");
        let pv: Vec<(&str, i64)> = vec![
            ("n", n as i64), ("c", c as i64), ("h", h as i64), ("w", w as i64),
        ];
        let chw = [c, h, w];
        out.push(
            Artifact::synthetic(
                &format!("bn_train-spatial-{base}-f32"), "batchnorm",
                "spatial_train", "fwd",
                vec![f32s(&shape), f32s(&[c]), f32s(&[c])],
                vec![f32s(&shape), f32s(&[c]), f32s(&[c])])
            .with_params(&pv).with_tag("prim"));
        out.push(
            Artifact::synthetic(
                &format!("bn_bwd-spatial-{base}-f32"), "batchnorm",
                "spatial_bwd", "bwd",
                vec![f32s(&shape), f32s(&shape), f32s(&[c]), f32s(&[c]),
                     f32s(&[c])],
                vec![f32s(&shape), f32s(&[c]), f32s(&[c])])
            .with_params(&pv).with_tag("prim"));
        out.push(
            Artifact::synthetic(
                &format!("bn_train-peract-{base}-f32"), "batchnorm",
                "peract_train", "fwd",
                vec![f32s(&shape), f32s(&chw), f32s(&chw)],
                vec![f32s(&shape), f32s(&chw), f32s(&chw)])
            .with_params(&pv).with_tag("prim"));
        out.push(
            Artifact::synthetic(
                &format!("bn_bwd-peract-{base}-f32"), "batchnorm",
                "peract_bwd", "bwd",
                vec![f32s(&shape), f32s(&shape), f32s(&chw), f32s(&chw),
                     f32s(&chw)],
                vec![f32s(&shape), f32s(&chw), f32s(&chw)])
            .with_params(&pv).with_tag("prim"));
        out.push(
            Artifact::synthetic(
                &format!("bn_infer-peract-{base}-f32"), "batchnorm",
                "peract_infer", "fwd",
                vec![f32s(&shape), f32s(&chw), f32s(&chw), f32s(&chw),
                     f32s(&chw)],
                vec![f32s(&shape)])
            .with_params(&pv).with_tag("prim"));
    }

    for ((n, c, h, w), win, stride, pad, mode) in POOL_SHAPES {
        let shape = [n, c, h, w];
        let ho = (h + 2 * pad.0 - win.0) / stride.0 + 1;
        let wo = (w + 2 * pad.1 - win.1) / stride.1 + 1;
        let oshape = [n, c, ho, wo];
        let base = format!("{mode}-n{n}c{c}h{h}w{w}k{}x{}u{}p{}",
                           win.0, win.1, stride.0, pad.0);
        let pv: Vec<(&str, i64)> = vec![
            ("n", n as i64), ("c", c as i64), ("h", h as i64), ("w", w as i64),
        ];
        out.push(
            Artifact::synthetic(&format!("pool_fwd-{base}-f32"), "pooling",
                                mode, "fwd", vec![f32s(&shape)],
                                vec![f32s(&oshape)])
            .with_params(&pv).with_str_param("mode", mode).with_tag("prim"));
        out.push(
            Artifact::synthetic(&format!("pool_bwd-{base}-f32"), "pooling",
                                mode, "bwd",
                                vec![f32s(&shape), f32s(&oshape),
                                     f32s(&oshape)],
                                vec![f32s(&shape)])
            .with_params(&pv).with_str_param("mode", mode).with_tag("prim"));
    }

    for (n, c, h, w) in SOFTMAX_SHAPES {
        let shape = [n, c, h, w];
        let base = format!("n{n}c{c}h{h}w{w}");
        let pv: Vec<(&str, i64)> = vec![
            ("n", n as i64), ("c", c as i64), ("h", h as i64), ("w", w as i64),
        ];
        for nm in ["softmax", "log_softmax"] {
            out.push(
                Artifact::synthetic(&format!("{nm}_fwd-{base}-f32"), "softmax",
                                    nm, "fwd", vec![f32s(&shape)],
                                    vec![f32s(&shape)])
                .with_params(&pv).with_tag("prim"));
            out.push(
                Artifact::synthetic(&format!("{nm}_bwd-{base}-f32"), "softmax",
                                    nm, "bwd",
                                    vec![f32s(&shape), f32s(&shape)],
                                    vec![f32s(&shape)])
                .with_params(&pv).with_tag("prim"));
        }
    }

    for (n, c, h, w) in ACT_SHAPES {
        let shape = [n, c, h, w];
        let pv: Vec<(&str, i64)> = vec![
            ("n", n as i64), ("c", c as i64), ("h", h as i64), ("w", w as i64),
        ];
        for mode in ACT_MODES {
            out.push(
                Artifact::synthetic(
                    &format!("act_fwd-{mode}-n{n}c{c}h{h}w{w}-f32"),
                    "activation", mode, "fwd", vec![f32s(&shape)],
                    vec![f32s(&shape)])
                .with_params(&pv).with_tag("prim"));
            out.push(
                Artifact::synthetic(
                    &format!("act_bwd-{mode}-n{n}c{c}h{h}w{w}-f32"),
                    "activation", mode, "bwd",
                    vec![f32s(&shape), f32s(&shape)], vec![f32s(&shape)])
                .with_params(&pv).with_tag("prim"));
        }
    }

    for (n, c, h, w) in LRN_SHAPES {
        let shape = [n, c, h, w];
        out.push(
            Artifact::synthetic(&format!("lrn_fwd-n{n}c{c}h{h}w{w}-f32"),
                                "lrn", "cross_channel", "fwd",
                                vec![f32s(&shape)], vec![f32s(&shape)])
            .with_params(&[("n", n as i64), ("c", c as i64), ("h", h as i64),
                           ("w", w as i64)])
            .with_tag("prim"));
    }

    let (n, c, h, w) = (4usize, 16usize, 14usize, 14usize);
    let shape = [n, c, h, w];
    for op in ["add", "mul"] {
        out.push(
            Artifact::synthetic(
                &format!("op_tensor-{op}-n{n}c{c}h{h}w{w}-f32"), "tensor_op",
                op, "fwd", vec![f32s(&shape), f32s(&shape)],
                vec![f32s(&shape)])
            .with_params(&[("n", n as i64), ("c", c as i64), ("h", h as i64),
                           ("w", w as i64)])
            .with_tag("prim"));
    }

    // CTC loss.
    let (b, t, v, l) = (4usize, 8usize, 6usize, 3usize);
    out.push(
        Artifact::synthetic(
            &format!("ctc_loss-b{b}t{t}v{v}l{l}-f32"), "ctc", "forward",
            "fwd",
            vec![f32s(&[b, t, v]), sp(&[b, l], DType::I32),
                 sp(&[b], DType::I32), sp(&[b], DType::I32)],
            vec![f32s(&[b])])
        .with_params(&[("b", b as i64), ("t", t as i64), ("v", v as i64),
                       ("l", l as i64)])
        .with_tag("prim"));
}

fn rnn_artifact(rc: &RnnConfig, variant: &str, tag: &str) -> Artifact {
    let (t, b, x, h) = (rc.t, rc.b, rc.x, rc.hid);
    let inputs = match rc.cell {
        "lstm" => vec![f32s(&[t, b, x]), f32s(&[b, h]), f32s(&[b, h]),
                       f32s(&[4 * h, x]), f32s(&[4 * h, h])],
        "gru" => vec![f32s(&[t, b, x]), f32s(&[b, h]), f32s(&[3 * h, x]),
                      f32s(&[3 * h, h])],
        _ => vec![f32s(&[t, b, x]), f32s(&[b, h]), f32s(&[h, x]),
                  f32s(&[h, h])],
    };
    let hidden = if variant == "bidir" { 2 * h } else { h };
    Artifact::synthetic(
        &format!("rnn-{}-{variant}-{}-f32", rc.cell, rc.sig_params()), "rnn",
        &format!("{}_{variant}", rc.cell), "fwd", inputs,
        vec![f32s(&[t, b, hidden])])
    .with_params(&[("t", t as i64), ("b", b as i64), ("x", x as i64),
                   ("hid", h as i64)])
    .with_str_param("cell", rc.cell)
    .with_str_param("act", rc.act)
    .with_tag(tag)
}

fn emit_rnn_family(out: &mut Vec<Artifact>) {
    for rc in &rnn_configs() {
        out.push(rnn_artifact(rc, "fused", "rnn"));
    }
    // ablation sweep: fused vs naive LSTM over T.
    for t in RNN_ABLATION_T {
        let rc = RnnConfig { cell: "lstm", t, b: 8, x: 32, hid: 32,
                             act: "tanh" };
        out.push(rnn_artifact(&rc, "fused", "abl-rnn"));
        out.push(rnn_artifact(&rc, "naive", "abl-rnn"));
    }
    // bidirectional exemplar.
    out.push(rnn_artifact(&rnn_configs()[0], "bidir", "rnn"));
}

fn emit_cnn(out: &mut Vec<Artifact>) {
    use cnn::*;
    let param_specs = || -> Vec<TensorSpec> {
        vec![
            f32s(&[C1, CHANNELS, 3, 3]), // w1
            f32s(&[C1]),                 // g1
            f32s(&[C1]),                 // b1
            f32s(&[C2, C1, 3, 3]),       // w2
            f32s(&[C2]),                 // g2
            f32s(&[C2]),                 // b2
            f32s(&[FEAT, CLASSES]),      // wd
        ]
    };
    let xspec = f32s(&[BATCH, CHANNELS, IMAGE, IMAGE]);
    let lspec = sp(&[BATCH], DType::I32);
    let pv: Vec<(&str, i64)> = vec![
        ("image", IMAGE as i64), ("channels", CHANNELS as i64),
        ("classes", CLASSES as i64), ("c1", C1 as i64), ("c2", C2 as i64),
        ("hidden_hw", HIDDEN_HW as i64), ("batch", BATCH as i64),
    ];

    let mut train_in = param_specs();
    train_in.push(xspec.clone());
    train_in.push(lspec.clone());
    let mut train_out = param_specs();
    train_out.push(f32s(&[])); // scalar loss
    out.push(Artifact::synthetic("cnn_train-f32", "model", "cnn_train",
                                 "fwd", train_in, train_out)
        .with_params(&pv).with_tag("e2e"));

    let mut infer_in = param_specs();
    infer_in.push(xspec.clone());
    out.push(Artifact::synthetic(
        "cnn_infer-f32", "model", "cnn_infer", "fwd", infer_in,
        vec![f32s(&[BATCH, CLASSES]), sp(&[BATCH], DType::I32)])
        .with_params(&pv).with_tag("e2e"));

    out.push(Artifact::synthetic(
        "cnn_datagen-f32", "model", "cnn_datagen", "fwd",
        vec![sp(&[2], DType::U32)], vec![xspec, lspec])
        .with_params(&pv).with_tag("e2e"));

    out.push(Artifact::synthetic("cnn_init-f32", "model", "cnn_init", "fwd",
                                 Vec::new(), param_specs())
        .with_params(&pv).with_tag("e2e"));
}

/// The full builtin artifact set (same signatures as `make artifacts`).
pub fn builtin_artifacts() -> Vec<Artifact> {
    let mut out = Vec::with_capacity(320);
    emit_conv_family(&mut out);
    emit_fusion_family(&mut out);
    emit_primitives(&mut out);
    emit_rnn_family(&mut out);
    emit_cnn(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;
    use crate::types::ProblemSig;

    #[test]
    fn builtin_manifest_parses_and_indexes() {
        let m = Manifest::builtin();
        assert!(m.synthetic);
        assert!(m.len() > 200, "builtin set has {} artifacts", m.len());
        // every conv signature round-trips through the parser and matches
        // its recorded algo/dtype (same check loads_real_manifest_if_present
        // runs against the AOT'd set)
        for a in m.by_primitive("conv") {
            let (p, algo, _) = ProblemSig::parse_artifact(&a.sig).unwrap();
            assert_eq!(algo, a.algo, "{}", a.sig);
            assert_eq!(p.dtype, a.dtype, "{}", a.sig);
            assert_eq!(p.layout == Layout::Nhwc, a.sig.contains("-nhwc"),
                       "{}", a.sig);
        }
    }

    #[test]
    fn builtin_covers_test_surface() {
        let m = Manifest::builtin();
        for sig in [
            "conv_fwd-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32",
            "conv_fwd-winograd-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32",
            "conv_bwd-gemm-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32",
            "conv_wrw-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32",
            "conv_fwd-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-bk32",
            "conv_fwd-winograd-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-wt4",
            "conv_fwd-fft-n4c4h28w28k8r5s5u1v1p2q2l1j1g1-f32",
            "conv_fwd-direct-n4c16h14w14k32r3s3u1v1p1q1l1j1g1-i8",
            // NHWC layout axis: native fwd zoo on exemplar configs,
            // transpose-at-boundary winograd/fft and bwd/wrw, a bf16
            // slice, tuned per-layout variants, and the dedicated
            // depthwise solver (both layouts + tuned channel blocks)
            "conv_fwd-direct-n4c16h28w28k16r1s1u1v1p0q0l1j1g1-f32-nhwc",
            "conv_fwd-gemm-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-nhwc",
            "conv_fwd-winograd-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-nhwc",
            "conv_fwd-fft-n4c4h28w28k8r5s5u1v1p2q2l1j1g1-f32-nhwc",
            "conv_bwd-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-nhwc",
            "conv_wrw-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-nhwc",
            "conv_fwd-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16-nhwc",
            "conv_fwd-gemm-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16-nhwc",
            "conv_fwd-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-nhwc-bk32",
            "conv_fwd-gemm-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-nhwc-gt0",
            "conv_fwd-depthwise-n4c32h14w14k32r3s3u1v1p1q1l1j1g32-f32",
            "conv_fwd-depthwise-n4c32h14w14k32r3s3u1v1p1q1l1j1g32-f32-nhwc",
            "conv_fwd-depthwise-n2c8h28w28k8r3s3u2v2p1q1l1j1g8-f32",
            "conv_fwd-depthwise-n2c8h28w28k8r3s3u2v2p1q1l1j1g8-f32-nhwc",
            "conv_fwd-depthwise-n4c32h14w14k32r3s3u1v1p1q1l1j1g32-f32-bk16",
            (
                "conv_fwd-depthwise-n4c32h14w14k32r3s3u1v1p1q1l1j1g32\
                 -f32-nhwc-bk16"
            ),
            // mixed-precision surface: bf16 covers the full fwd zoo on
            // exemplar configs, bwd/wrw on the universal pair, tuned
            // variants per dtype, and the Table II executable plans
            "conv_fwd-gemm-n4c16h28w28k16r1s1u1v1p0q0l1j1g1-bf16",
            "conv_fwd-winograd-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16",
            "conv_fwd-fft-n4c4h28w28k8r5s5u1v1p2q2l1j1g1-bf16",
            "conv_fwd-implicit-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16",
            "conv_bwd-winograd-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16",
            "conv_bwd-gemm-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16",
            "conv_wrw-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16",
            "conv_fwd-gemm-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f16",
            "conv_fwd-winograd-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f16",
            "conv_fwd-gemm-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16-gt1",
            "conv_fwd-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16-bk32",
            "conv_fwd-winograd-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16-wt4",
            "conv_fwd-gemm-n4c64h14w14k64r1s1u1v1p0q0l1j1g1-bf16",
            "cba-relu-n4c16h28w28k32r1s1u1v1p0q0l1j1g1-bf16",
            "cbna-relu-n2c8h14w14k8r3s3u1v1p1q1l1j1g1-bf16",
            "cba-relu-n4c32h14w14k8r3s3u1v1p1q1l1j1g1-f32",
            "conv_fwd-winograd-n4c32h14w14k8r3s3u1v1p1q1l1j1g1-f32",
            "bias-4x8x14x14-f32",
            "act-relu-4x8x14x14-f32",
            "cba-relu-n4c16h28w28k32r1s1u1v1p0q0l1j1g1-f32",
            "cba-relu-n4c16h28w28k32r1s1u1v1p0q0l1j1g1-f32-nhwc",
            "conv_fwd-direct-n4c16h28w28k32r1s1u1v1p0q0l1j1g1-f32",
            "bias-4x32x28x28-f32",
            "act-relu-4x32x28x28-f32",
            "bna-relu-n4c16h28w28-f32",
            "bn_infer-spatial-n4c16h28w28-f32",
            "act-relu-4x16x28x28-f32",
            "cbna-relu-n2c8h14w14k8r3s3u1v1p1q1l1j1g1-f32",
            "cbna-relu-n2c8h14w14k8r3s3u2v2p1q1l1j1g1-f32",
            "rnn-lstm-fused-t16b8x32h32-f32",
            "rnn-lstm-naive-t16b8x32h32-f32",
            "rnn-lstm-bidir-t16b8x32h32-f32",
            "rnn-gru-fused-t16b8x32h32-f32",
            "rnn-vanilla-fused-t16b8x32h32-f32",
            "ctc_loss-b4t8v6l3-f32",
            "cnn_train-f32",
            "cnn_infer-f32",
            "cnn_datagen-f32",
            "cnn_init-f32",
            "pool_fwd-max-n4c16h28w28k2x2u2p0-f32",
            "bn_train-spatial-n4c16h14w14-f32",
            "softmax_fwd-n4c10h1w1-f32",
            "act_fwd-relu-n4c16h28w28-f32",
        ] {
            assert!(m.get(sig).is_some(), "builtin manifest missing {sig}");
        }
        // the "accepted but never AOT'd" fusion plan must stay missing
        assert!(m.get("cba-relu-n4c16h28w28k13r1s1u1v1p0q0l1j1g1-f32")
            .is_none());
    }

    #[test]
    fn builtin_fig6_panels_complete() {
        let m = Manifest::builtin();
        for panel in ["fig6a", "fig6b", "fig6c", "fig6d", "fig6e", "fig6f"] {
            let count = m.by_tag(panel).count();
            assert!(count >= 8, "{panel}: {count} artifacts");
        }
        // 1x1 panels carry no winograd artifacts
        assert!(m.by_tag("fig6a").all(|a| a.algo != "winograd"));
    }

    #[test]
    fn fusion_artifacts_record_mdgraph_conv_algo() {
        // every conv-bearing fusion artifact names its executing conv
        // algorithm, and the winograd exemplar really selects winograd
        let m = Manifest::builtin();
        for a in m.by_primitive("fusion") {
            if a.algo == "cba" || a.algo == "cbna" {
                assert!(a.str_param("conv_algo").is_some(), "{}", a.sig);
            }
        }
        let wino = m
            .require("cba-relu-n4c32h14w14k8r3s3u1v1p1q1l1j1g1-f32")
            .unwrap();
        assert_eq!(wino.str_param("conv_algo"), Some(algo::WINOGRAD));
    }

    #[test]
    fn gemm_tile_variants_carry_tile_param() {
        let m = Manifest::builtin();
        for gt in gemm_tile_grid() {
            let sig = format!(
                "conv_fwd-gemm-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-gt{gt}"
            );
            let a = m.require(&sig).unwrap();
            assert_eq!(a.tuning.get(crate::solvers::GEMM_TILE_PARAM),
                       Some(&(gt as i64)), "{sig}");
            assert!(a.has_tag("tune-gemm"));
        }
    }

    #[test]
    fn winograd_tune_variants_carry_thread_param() {
        let m = Manifest::builtin();
        for wt in WINOGRAD_TILE_THREADS {
            let sig = format!(
                "conv_fwd-winograd-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-wt{wt}"
            );
            let a = m.require(&sig).unwrap();
            assert_eq!(a.tuning.get(crate::solvers::WINO_THREADS_PARAM),
                       Some(&(wt as i64)), "{sig}");
            assert!(a.has_tag("tune-wino"));
        }
    }

    #[test]
    fn nhwc_artifacts_carry_channels_last_specs() {
        // sig params stay logical NCHW; the spec shapes are physical
        let m = Manifest::builtin();
        let a = m
            .require("conv_fwd-gemm-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-nhwc")
            .unwrap();
        assert_eq!(a.inputs[0].shape, vec![4, 28, 28, 16]);
        assert_eq!(a.inputs[1].shape, vec![32, 3, 3, 16]);
        assert_eq!(a.outputs[0].shape, vec![4, 28, 28, 32]);
        let b = m
            .require("conv_bwd-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-nhwc")
            .unwrap();
        assert_eq!(b.inputs[0].shape, vec![4, 28, 28, 32]);
        assert_eq!(b.outputs[0].shape, vec![4, 28, 28, 16]);
    }

    #[test]
    fn depthwise_exemplars_mirror_solver_grid() {
        // every grid point the depthwise solver can propose has an
        // AOT'd artifact in both layouts (no silently unservable tile)
        use crate::solvers::Solver;
        let m = Manifest::builtin();
        let dw = grouped_configs()[0];
        let sig = dw.problem_sig("fwd", DType::F32);
        for tp in crate::solvers::DepthwiseSolver.tuning_grid(&sig) {
            let bk = tp.get(crate::solvers::BLOCK_K_PARAM).unwrap();
            for suffix in ["", "-nhwc"] {
                let s = format!(
                    "conv_fwd-depthwise-{}-f32{suffix}-bk{bk}",
                    dw.sig_params());
                assert!(m.get(&s).is_some(), "missing {s}");
            }
        }
    }

    #[test]
    fn conv_artifacts_carry_solver_workspace() {
        // artifact workspace comes from the same formula the find step
        // reports (solvers::workspace_for) — no drift between the two
        let m = Manifest::builtin();
        for a in m.by_primitive("conv") {
            let (sig, algo_name, _) =
                ProblemSig::parse_artifact(&a.sig).unwrap();
            assert_eq!(a.workspace_bytes,
                       crate::solvers::workspace_for(&algo_name, &sig),
                       "{}", a.sig);
        }
    }

    #[test]
    fn builtin_matches_solver_applicability() {
        // every fwd conv artifact's algo — across all emitted dtypes —
        // must be applicable per the solver registry (aot.fwd_algos <->
        // solvers::applicable contract, now a per-dtype axis)
        let m = Manifest::builtin();
        for a in m.by_primitive("conv") {
            if a.direction != "fwd" {
                continue;
            }
            let (sig, algo, _) = ProblemSig::parse_artifact(&a.sig).unwrap();
            let names: Vec<String> = crate::solvers::applicable(&sig)
                .iter()
                .map(|s| s.name().to_string())
                .collect();
            assert!(names.contains(&algo),
                    "{}: algo {algo} not applicable ({names:?})", a.sig);
        }
    }

    #[test]
    fn bf16_tune_variants_carry_params_per_dtype() {
        // tuned variants are a per-dtype axis: the bf16 -gt/-bk/-wt
        // artifacts exist alongside the f32 ones and carry the same
        // tuning params, so a bf16 tuning session resolves bf16
        // artifacts (perf-db keys already include the dtype)
        let m = Manifest::builtin();
        for gt in gemm_tile_grid() {
            let sig = format!(
                "conv_fwd-gemm-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16-gt{gt}"
            );
            let a = m.require(&sig).unwrap();
            assert_eq!(a.tuning.get(crate::solvers::GEMM_TILE_PARAM),
                       Some(&(gt as i64)), "{sig}");
            assert_eq!(a.dtype, DType::Bf16);
        }
        for bk in DIRECT_BLOCK_K {
            let sig = format!(
                "conv_fwd-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-bf16-bk{bk}"
            );
            assert!(m.get(&sig).is_some(), "{sig}");
        }
    }

    #[test]
    fn bf16_fused_plans_record_table2_conv_algo() {
        // Table II: half precision fuses only through the direct kernel
        let m = Manifest::builtin();
        for a in m.by_primitive("fusion") {
            if a.dtype != DType::Bf16 {
                continue;
            }
            assert_eq!(a.str_param("conv_algo"), Some(algo::DIRECT),
                       "{}", a.sig);
        }
        assert!(m.by_primitive("fusion").any(|a| a.dtype == DType::Bf16),
                "builtin set must carry executable bf16 fusion plans");
    }
}
