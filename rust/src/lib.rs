//! # miopen-rs
//!
//! Reproduction of **"MIOpen: An Open Source Library For Deep Learning
//! Primitives"** (AMD, 2019) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see README.md):
//! - **L1/L2** (build time, Python): Pallas kernels + JAX graphs, AOT-lowered
//!   to HLO text artifacts by `make artifacts`.
//! - **L3** (this crate): the MIOpen library proper — descriptors, the
//!   solver registry, the find step, auto-tuning with a persistent perf-db,
//!   two-level kernel caching, the fusion API with its constraint metadata
//!   graph, and a multi-worker batched inference engine. Python never runs
//!   at request time; the binary is self-contained once `artifacts/` exists.
//!
//! Backend matrix: the default build is hermetic — every pipeline runs on
//! [`runtime::InterpBackend`], a pure-Rust reference executor serving the
//! builtin synthetic manifest ([`configs::builtin_artifacts`]). Building
//! with `--features pjrt` plus `make artifacts` upgrades the same code
//! paths to compiled PJRT kernels (`BackendChoice::auto` picks the best
//! available); the mock backend covers failure injection in tests.
//!
//! Paper-section → module map: see `docs/ARCHITECTURE.md` (§III find/db,
//! §III-A solvers, §III-B tuning, §IV algorithms, §V fusion, plus the
//! serving engine this reproduction grows on top).
//!
//! Quick start (see `examples/quickstart.rs`):
//! ```no_run
//! use miopen_rs::prelude::*;
//! let handle = Handle::new(Default::default()).unwrap();
//! let conv = ConvDesc::new((1, 1), (1, 1), (1, 1), ConvMode::CrossCorrelation, 1);
//! let problem = ConvProblem::forward(
//!     TensorDesc::nchw(4, 16, 28, 28, DType::F32),
//!     FilterDesc::kcrs(32, 16, 3, 3, DType::F32),
//!     conv,
//! );
//! let results = handle.find_convolution(&problem).unwrap();
//! println!("best algo: {}", results[0].algo);
//! ```

// Public-API documentation is enforced: the paper-facing core (types,
// solvers, find, tuning, perfmodel) is lint-clean; infrastructure
// modules below carry an explicit allow until their doc pass lands —
// shrink this list, never grow it.
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod bench;
#[allow(missing_docs)]
pub mod cache;
#[allow(missing_docs)]
pub mod cli;
#[allow(missing_docs)]
pub mod configs;
#[allow(missing_docs)]
pub mod db;
#[allow(missing_docs)]
pub mod descriptors;
pub mod find;
#[allow(missing_docs)]
pub mod fusion;
#[allow(missing_docs)]
pub mod handle;
pub mod immediate;
#[allow(missing_docs)]
pub mod manifest;
#[allow(missing_docs)]
pub mod metrics;
pub mod perfmodel;
#[allow(missing_docs)]
pub mod primitives;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod serve;
pub mod solvers;
#[allow(missing_docs)]
pub mod testutil;
pub mod tuning;
pub mod types;
#[allow(missing_docs)]
pub mod util;
#[allow(missing_docs)]
pub mod workload;

/// Convenience re-exports for library users.
pub mod prelude {
    pub use crate::descriptors::{
        ActivationDesc, ActivationMode, BnMode, ConvDesc, ConvMode,
        FilterDesc, LrnDesc, PoolDesc, PoolMode, RnnDesc, RnnCell,
        RnnDirection, RnnInputMode, SoftmaxMode, TensorDesc,
    };
    pub use crate::find::{ConvAlgoPerf, ConvProblem, Direction};
    pub use crate::fusion::{FusionOp, FusionPlan};
    pub use crate::handle::{Handle, HandleOptions};
    pub use crate::immediate::{
        ImmediateOptions, Refiner, Solution, SolutionSource,
    };
    pub use crate::types::{DType, MiopenError, Result};
}
