//! # miopen-rs
//!
//! Reproduction of **"MIOpen: An Open Source Library For Deep Learning
//! Primitives"** (AMD, 2019) as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see README.md):
//! - **L1/L2** (build time, Python): Pallas kernels + JAX graphs, AOT-lowered
//!   to HLO text artifacts by `make artifacts`.
//! - **L3** (this crate): the MIOpen library proper — descriptors, the
//!   solver registry, the find step, auto-tuning with a persistent perf-db,
//!   two-level kernel caching, the fusion API with its constraint metadata
//!   graph, and a multi-worker batched inference engine. Python never runs
//!   at request time; the binary is self-contained once `artifacts/` exists.
//!
//! Backend matrix: the default build is hermetic — every pipeline runs on
//! [`runtime::InterpBackend`], a pure-Rust reference executor serving the
//! builtin synthetic manifest ([`configs::builtin_artifacts`]). Building
//! with `--features pjrt` plus `make artifacts` upgrades the same code
//! paths to compiled PJRT kernels (`BackendChoice::auto` picks the best
//! available); the mock backend covers failure injection in tests.
//!
//! Quick start (see `examples/quickstart.rs`):
//! ```no_run
//! use miopen_rs::prelude::*;
//! let handle = Handle::new(Default::default()).unwrap();
//! let conv = ConvDesc::new((1, 1), (1, 1), (1, 1), ConvMode::CrossCorrelation, 1);
//! let problem = ConvProblem::forward(
//!     TensorDesc::nchw(4, 16, 28, 28, DType::F32),
//!     FilterDesc::kcrs(32, 16, 3, 3, DType::F32),
//!     conv,
//! );
//! let results = handle.find_convolution(&problem).unwrap();
//! println!("best algo: {}", results[0].algo);
//! ```

pub mod bench;
pub mod cache;
pub mod cli;
pub mod configs;
pub mod db;
pub mod descriptors;
pub mod find;
pub mod fusion;
pub mod handle;
pub mod manifest;
pub mod metrics;
pub mod perfmodel;
pub mod primitives;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod testutil;
pub mod tuning;
pub mod types;
pub mod util;
pub mod workload;

/// Convenience re-exports for library users.
pub mod prelude {
    pub use crate::descriptors::{
        ActivationDesc, ActivationMode, BnMode, ConvDesc, ConvMode,
        FilterDesc, LrnDesc, PoolDesc, PoolMode, RnnDesc, RnnCell,
        RnnDirection, RnnInputMode, SoftmaxMode, TensorDesc,
    };
    pub use crate::find::{ConvAlgoPerf, ConvProblem, Direction};
    pub use crate::fusion::{FusionOp, FusionPlan};
    pub use crate::handle::{Handle, HandleOptions};
    pub use crate::types::{DType, MiopenError, Result};
}
