//! Test support: artifact location + a small property-testing harness
//! (standing in for `proptest`, which is unavailable offline — DESIGN.md
//! §Substitutions #5).

pub mod prop;

use std::path::PathBuf;

/// Locate the artifacts directory: $MIOPEN_RS_ARTIFACTS or <repo>/artifacts.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("MIOPEN_RS_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when the full artifact set exists (integration tests skip
/// gracefully otherwise so `cargo test` works pre-`make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
