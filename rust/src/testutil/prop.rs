//! Minimal property-testing harness.
//!
//! `proptest` is not in the offline crate closure, so this module provides
//! the subset the test suite needs: seeded generators, a `forall` runner
//! with failure reporting (seed + case index for reproduction), and greedy
//! input shrinking for integer vectors.

use crate::util::rng::SplitMix64;

pub const DEFAULT_CASES: usize = 256;

/// A generator: RNG -> value.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut SplitMix64) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut SplitMix64) -> T + 'static) -> Self {
        Self { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut SplitMix64) -> T {
        (self.f)(rng)
    }

    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |r| g(self.sample(r)))
    }
}

/// Uniform usize in [lo, hi] inclusive.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo <= hi);
    Gen::new(move |r| lo + r.below((hi - lo + 1) as u64) as usize)
}

pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |r| r.range_f64(lo, hi))
}

pub fn choice<T: Clone + 'static>(items: Vec<T>) -> Gen<T> {
    assert!(!items.is_empty());
    Gen::new(move |r| items[r.below(items.len() as u64) as usize].clone())
}

pub fn vec_of<T: 'static>(item: Gen<T>, len: Gen<usize>) -> Gen<Vec<T>> {
    Gen::new(move |r| {
        let n = len.sample(r);
        (0..n).map(|_| item.sample(r)).collect()
    })
}

/// Run `check` over `cases` random inputs; panics with the seed and case
/// number on the first failure so the case can be replayed exactly.
pub fn forall<T: std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    cases: usize,
    check: impl Fn(&T) -> Result<(), String>,
) {
    let seed = std::env::var("MIOPEN_RS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property '{name}' failed (seed={seed}, case={case}):\n  \
                 input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Like [`forall`], but on failure the counterexample is greedily
/// minimized with `shrink` (a candidate producer: smaller variants of the
/// input) before panicking. The panic message carries the seed, case
/// index, original input AND the shrunk input, so the minimal failing
/// case can be replayed directly.
pub fn forall_shrink<T: std::fmt::Debug + Clone + 'static>(
    name: &str,
    gen: &Gen<T>,
    cases: usize,
    shrink: impl Fn(&T) -> Vec<T>,
    check: impl Fn(&T) -> Result<(), String>,
) {
    let seed = std::env::var("MIOPEN_RS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = check(&input) {
            let shrunk =
                shrink_to_fixpoint(input.clone(), &shrink,
                                   |t| check(t).is_err());
            let shrunk_msg = check(&shrunk).err().unwrap_or_else(|| msg.clone());
            panic!(
                "property '{name}' failed (seed={seed}, case={case}):\n  \
                 input: {input:?}\n  shrunk: {shrunk:?}\n  error: {shrunk_msg}"
            );
        }
    }
}

/// Repeatedly replace `input` with the first still-failing shrink
/// candidate until no candidate fails (or an iteration bound trips).
pub fn shrink_to_fixpoint<T: Clone>(
    mut input: T,
    candidates: &impl Fn(&T) -> Vec<T>,
    still_fails: impl Fn(&T) -> bool,
) -> T {
    for _ in 0..10_000 {
        let Some(next) = candidates(&input)
            .into_iter()
            .find(|c| still_fails(c))
        else {
            return input;
        };
        input = next;
    }
    input
}

/// Shrink candidates for a vec: every copy with one element removed.
pub fn vec_removals<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    (0..v.len())
        .map(|i| {
            let mut c = v.to_vec();
            c.remove(i);
            c
        })
        .collect()
}

/// Greedy shrink for a vec-shaped counterexample: try dropping elements
/// while the failure persists; returns the smallest failing input found.
/// (Convenience wrapper over [`shrink_to_fixpoint`] + [`vec_removals`].)
pub fn shrink_vec<T: Clone>(
    input: Vec<T>,
    still_fails: impl Fn(&[T]) -> bool,
) -> Vec<T> {
    shrink_to_fixpoint(input, &|v: &Vec<T>| vec_removals(v),
                       |v| still_fails(v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_valid_property() {
        forall("sum-commutes", &vec_of(usize_in(0, 100), usize_in(0, 10)),
               200, |v| {
                   let a: usize = v.iter().sum();
                   let b: usize = v.iter().rev().sum();
                   if a == b { Ok(()) } else { Err("sum not commutative".into()) }
               });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn forall_reports_failure() {
        forall("always-fails", &usize_in(0, 10), 10, |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk: [7]")]
    fn forall_shrink_minimizes_counterexample() {
        // failure: vec contains a 7 — the shrunk case must be exactly [7]
        forall_shrink(
            "contains-seven",
            &vec_of(usize_in(0, 9), usize_in(8, 12)),
            500,
            |v| vec_removals(v),
            |v| {
                if v.contains(&7) {
                    Err("found a 7".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn shrink_to_fixpoint_stops_at_minimum() {
        let out = shrink_to_fixpoint(
            vec![1, 7, 3, 9, 7],
            &|v: &Vec<i32>| vec_removals(v),
            |v| v.contains(&7),
        );
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn shrink_finds_minimal_case() {
        // failure: vec contains a 7
        let input = vec![1, 7, 3, 9, 7];
        let out = shrink_vec(input, |v| v.contains(&7));
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = SplitMix64::new(5);
        let g = usize_in(3, 9);
        for _ in 0..500 {
            let v = g.sample(&mut rng);
            assert!((3..=9).contains(&v));
        }
        let c = choice(vec!["a", "b"]);
        for _ in 0..50 {
            let v = c.sample(&mut rng);
            assert!(v == "a" || v == "b");
        }
    }
}
