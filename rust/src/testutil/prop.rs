//! Minimal property-testing harness.
//!
//! `proptest` is not in the offline crate closure, so this module provides
//! the subset the test suite needs: seeded generators, a `forall` runner
//! with failure reporting (seed + case index for reproduction), and greedy
//! input shrinking for integer vectors.

use crate::util::rng::SplitMix64;

pub const DEFAULT_CASES: usize = 256;

/// A generator: RNG -> value.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut SplitMix64) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut SplitMix64) -> T + 'static) -> Self {
        Self { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut SplitMix64) -> T {
        (self.f)(rng)
    }

    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |r| g(self.sample(r)))
    }
}

/// Uniform usize in [lo, hi] inclusive.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo <= hi);
    Gen::new(move |r| lo + r.below((hi - lo + 1) as u64) as usize)
}

pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |r| r.range_f64(lo, hi))
}

pub fn choice<T: Clone + 'static>(items: Vec<T>) -> Gen<T> {
    assert!(!items.is_empty());
    Gen::new(move |r| items[r.below(items.len() as u64) as usize].clone())
}

pub fn vec_of<T: 'static>(item: Gen<T>, len: Gen<usize>) -> Gen<Vec<T>> {
    Gen::new(move |r| {
        let n = len.sample(r);
        (0..n).map(|_| item.sample(r)).collect()
    })
}

/// Run `check` over `cases` random inputs; panics with the seed and case
/// number on the first failure so the case can be replayed exactly.
pub fn forall<T: std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    cases: usize,
    check: impl Fn(&T) -> Result<(), String>,
) {
    let seed = std::env::var("MIOPEN_RS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property '{name}' failed (seed={seed}, case={case}):\n  \
                 input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Greedy shrink for a vec-shaped counterexample: try dropping elements
/// while the failure persists; returns the smallest failing input found.
pub fn shrink_vec<T: Clone>(
    mut input: Vec<T>,
    still_fails: impl Fn(&[T]) -> bool,
) -> Vec<T> {
    loop {
        let mut shrunk = false;
        let mut i = 0;
        while i < input.len() {
            let mut cand = input.clone();
            cand.remove(i);
            if still_fails(&cand) {
                input = cand;
                shrunk = true;
            } else {
                i += 1;
            }
        }
        if !shrunk {
            return input;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_valid_property() {
        forall("sum-commutes", &vec_of(usize_in(0, 100), usize_in(0, 10)),
               200, |v| {
                   let a: usize = v.iter().sum();
                   let b: usize = v.iter().rev().sum();
                   if a == b { Ok(()) } else { Err("sum not commutative".into()) }
               });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn forall_reports_failure() {
        forall("always-fails", &usize_in(0, 10), 10, |_| Err("nope".into()));
    }

    #[test]
    fn shrink_finds_minimal_case() {
        // failure: vec contains a 7
        let input = vec![1, 7, 3, 9, 7];
        let out = shrink_vec(input, |v| v.contains(&7));
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = SplitMix64::new(5);
        let g = usize_in(3, 9);
        for _ in 0..500 {
            let v = g.sample(&mut rng);
            assert!((3..=9).contains(&v));
        }
        let c = choice(vec!["a", "b"]);
        for _ in 0..50 {
            let v = c.sample(&mut rng);
            assert!(v == "a" || v == "b");
        }
    }
}
