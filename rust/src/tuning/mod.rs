//! Auto-tuning infrastructure (paper §III-B).
//!
//! "The tuning parameters create a grid of possible values ... the tuning
//! infrastructure compiles and launches a unique kernel for each of these
//! combinations using a pruned search space approach. Once a kernel is
//! tuned ... they are serialized to a designated directory on the user's
//! system for future retrieval."
//!
//! A [`TuningSession`] races every tuning variant of every tunable solver
//! for a problem — the direct solver's `block_k` output tiles, the
//! winograd solver's transform-domain parallelism (`wt`), *and* the gemm
//! solver's blocked-engine `MC×NC` tile configs (`gt`, the CLBlast-style
//! tile-size search) — optionally pruning the grid before measuring, and
//! records each solver's winner in the user perf-db. The find step then
//! resolves tuned artifact variants through that db (the db-coherence
//! contract, docs/ARCHITECTURE.md).

use std::collections::BTreeMap;

use crate::find::ConvProblem;
use crate::handle::Handle;
use crate::solvers::TuningParams;
use crate::types::{MiopenError, Result};

/// Result of tuning one solver on one problem.
#[derive(Debug, Clone)]
pub struct TuneResult {
    /// Solver name ([`crate::types::algo`]).
    pub solver: String,
    /// The winning grid point (recorded in the user perf-db).
    pub best_params: TuningParams,
    /// Measured time of the winner (µs).
    pub best_time_us: f64,
    /// Measured time of the untuned default artifact, when it exists.
    pub default_time_us: Option<f64>,
    /// (params, measured µs) for every evaluated grid point.
    pub evaluated: Vec<(TuningParams, f64)>,
    /// Grid points dropped by the pruned-search heuristic.
    pub pruned_out: usize,
}

impl TuneResult {
    /// Speedup of the tuned variant over the default artifact.
    pub fn speedup_vs_default(&self) -> Option<f64> {
        self.default_time_us.map(|d| d / self.best_time_us)
    }
}

/// Knobs for a tuning session.
#[derive(Debug, Clone, Default)]
pub struct TuneOptions {
    /// Keep only the `prune_keep` most promising grid points before
    /// measuring (the paper's "pruned search space approach").
    /// 0 = measure the full grid.
    pub prune_keep: usize,
}

/// One auto-tuning run over a handle (borrows its backend + dbs).
pub struct TuningSession<'h> {
    handle: &'h Handle,
    opts: TuneOptions,
}

impl<'h> TuningSession<'h> {
    /// Session with default options (full-grid measurement).
    pub fn new(handle: &'h Handle) -> Self {
        Self { handle, opts: TuneOptions::default() }
    }

    /// Session with explicit [`TuneOptions`].
    pub fn with_options(handle: &'h Handle, opts: TuneOptions) -> Self {
        Self { handle, opts }
    }

    /// Tune every tunable solver applicable to `problem`; persist winners
    /// in the user perf-db. Returns one result per tuned solver.
    pub fn tune_convolution(&self, problem: &ConvProblem)
        -> Result<Vec<TuneResult>> {
        let sig = problem.sig()?;
        let key = sig.db_key();
        let mut results = Vec::new();

        for solver in crate::solvers::applicable(&sig) {
            let grid = solver.tuning_grid(&sig);
            if grid.is_empty() {
                continue;
            }

            // Keep only grid points whose tuned artifact actually exists.
            let manifest = self.handle.manifest();
            let mut available: Vec<TuningParams> = grid
                .into_iter()
                .filter(|tp| {
                    manifest
                        .get(&solver.artifact_sig(&sig, Some(tp)))
                        .is_some()
                })
                .collect();
            if available.is_empty() {
                continue;
            }

            // Pruned search: bigger tiles / wider parallelism amortize
            // fixed costs until they exceed the problem, so prefer the
            // largest feasible parameter values and drop the tail of the
            // grid (solver-agnostic: block_k and wt grids both rank by
            // their single knob).
            let mut pruned_out = 0;
            if self.opts.prune_keep > 0 && available.len() > self.opts.prune_keep {
                available.sort_by_key(|tp| {
                    std::cmp::Reverse(tp.values().copied().max().unwrap_or(0))
                });
                pruned_out = available.len() - self.opts.prune_keep;
                available.truncate(self.opts.prune_keep);
            }

            let mut evaluated = Vec::new();
            for tp in &available {
                let art_sig = solver.artifact_sig(&sig, Some(tp));
                let time = (|| -> Result<f64> {
                    let exe = self.handle.compile_sig(&art_sig)?;
                    let inputs = self.handle.random_inputs(&art_sig)?;
                    self.handle.time_exec(&exe, &inputs)
                })();
                match time {
                    Ok(t) => evaluated.push((tp.clone(), t)),
                    Err(_) => continue, // failed variant: skip, keep tuning
                }
            }
            if evaluated.is_empty() {
                continue;
            }

            let default_time = {
                let default_sig = solver.artifact_sig(&sig, None);
                manifest.get(&default_sig).and_then(|_| {
                    let exe = self.handle.compile_sig(&default_sig).ok()?;
                    let inputs = self.handle.random_inputs(&default_sig).ok()?;
                    self.handle.time_exec(&exe, &inputs).ok()
                })
            };

            let (best_params, best_time_us) = evaluated
                .iter()
                .min_by(|a, b| a.1.total_cmp(&b.1))
                .map(|(p, t)| (p.clone(), *t))
                .expect("non-empty");

            self.handle.user_perf.set_timed(
                &key,
                solver.name(),
                best_params.clone(),
                best_time_us,
            );

            results.push(TuneResult {
                solver: solver.name().to_string(),
                best_params,
                best_time_us,
                default_time_us: default_time,
                evaluated,
                pruned_out,
            });
        }

        if results.is_empty() {
            return Err(MiopenError::NotApplicable(format!(
                "no tunable solver with artifacts for {key}"
            )));
        }

        // db-coherence: the find-db entry for this problem (if any) was
        // benchmarked against the pre-tuning artifact set — its times and
        // implied signatures would shadow the new winners forever. Drop
        // it so the next find re-benchmarks with the tuned variants.
        self.handle.user_find.remove(&key);

        self.handle.save_dbs()?;
        Ok(results)
    }
}

/// Pretty-print tuned params (CLI + logs).
pub fn format_params(p: &BTreeMap<String, i64>) -> String {
    p.iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_params_stable_order() {
        let p = BTreeMap::from([
            ("block_k".to_string(), 32i64),
            ("a".to_string(), 1i64),
        ]);
        assert_eq!(format_params(&p), "a=1,block_k=32");
    }

    #[test]
    fn default_options_measure_full_grid() {
        assert_eq!(TuneOptions::default().prune_keep, 0);
    }
}
