//! The library handle (`miopenHandle_t` analog, paper §III-D).
//!
//! One `Handle` owns the backend (PJRT CPU client or the mock), the
//! two-level kernel cache, the artifact manifest, the find/perf databases
//! (system + user overlay) and the GCN perf model. All primitive and
//! fusion entry points hang off it.
//!
//! `Handle` is `Send + Sync`: the mutable state (user dbs, RNG, caches)
//! is mutex-guarded and backends/executables are `Send + Sync`, so one
//! handle can be shared by the serve engine's worker threads (see
//! README, "Serving concurrency model").
//!
//! The manifest and system dbs sit behind `RwLock<Arc<..>>` so the serve
//! engine's drain/reload path ([`Handle::reload_artifacts`]) can swap a
//! freshly tuned artifact set in-place while workers keep their borrowed
//! `&Handle` — readers clone the `Arc` (one atomic inc, no contention on
//! the hot path) and keep a consistent view for the whole operation.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::cache::{compile_cached, CacheStats, DiskCache, ExecCache};
use crate::db::{embedded_find_db, embedded_perf_db, DbStore, FindDb,
                PerfDb, ShardedFindDb, ShardedPerfDb};
use crate::manifest::Manifest;
use crate::perfmodel::GcnModel;
#[cfg(feature = "pjrt")]
use crate::runtime::CpuBackend;
use crate::runtime::{Backend, Executable, HostTensor, InterpBackend,
                     MockBackend, MockConfig};
use crate::types::{MiopenError, Result};
use crate::util::rng::SplitMix64;

/// Backend selection for handle creation — the analog of creating the
/// `miopenHandle` with a HIP stream vs an OpenCL context (§III-D).
pub enum BackendChoice {
    /// PJRT CPU over the AOT'd HLO artifacts (requires `make artifacts`
    /// and a real `xla` dependency behind the `pjrt` feature).
    #[cfg(feature = "pjrt")]
    Cpu,
    /// Pure-Rust reference executor — hermetic, the default.
    Interp,
    Mock(MockConfig),
}

impl BackendChoice {
    /// Best available backend: PJRT CPU when compiled with `pjrt` AND the
    /// artifact set exists (i.e. `make artifacts` has run); the interp
    /// backend otherwise. This is how building the artifacts "upgrades"
    /// the library from reference numerics to compiled kernels without
    /// any call-site change.
    pub fn auto() -> Self {
        #[cfg(feature = "pjrt")]
        {
            // Probe client creation too: pjrt builds against the checked-in
            // xla stub (or a broken install) must fall back to interp
            // instead of failing every Handle::new.
            if crate::testutil::artifacts_available()
                && CpuBackend::new().is_ok() {
                return BackendChoice::Cpu;
            }
        }
        BackendChoice::Interp
    }
}

impl Default for BackendChoice {
    fn default() -> Self {
        Self::auto()
    }
}

pub struct HandleOptions {
    pub backend: BackendChoice,
    /// Artifact directory; None = `<repo>/artifacts` or $MIOPEN_RS_ARTIFACTS.
    pub artifacts_dir: Option<PathBuf>,
    /// User db directory; None = $MIOPEN_RS_DB_DIR or ~/.config/miopen-rs.
    pub db_dir: Option<PathBuf>,
    /// Force db read-only mode: saves become counted no-ops and the
    /// embedded compile-time db backs the find-db. Also triggered by
    /// `MIOPEN_RS_DB_READONLY=1` or an unwritable db directory.
    pub db_read_only: bool,
    /// In-memory executable cache capacity.
    pub exec_cache_capacity: usize,
    /// Timed iterations per algorithm in the find step.
    pub find_iters: usize,
    /// Warmup runs before timing (the §III-C warmup recommendation).
    pub warmup_iters: usize,
    pub seed: u64,
}

impl Default for HandleOptions {
    fn default() -> Self {
        Self {
            backend: BackendChoice::auto(),
            artifacts_dir: None,
            db_dir: None,
            db_read_only: false,
            exec_cache_capacity: 256,
            find_iters: 3,
            warmup_iters: 1,
            seed: 0x5EED,
        }
    }
}

pub struct Handle {
    pub(crate) backend: Box<dyn Backend>,
    manifest: RwLock<Arc<Manifest>>,
    pub(crate) exec_cache: ExecCache,
    pub(crate) disk_cache: DiskCache,
    system_find: RwLock<Arc<FindDb>>,
    pub(crate) user_find: ShardedFindDb,
    system_perf: RwLock<Arc<PerfDb>>,
    pub(crate) user_perf: ShardedPerfDb,
    pub(crate) db_store: DbStore,
    pub(crate) model: GcnModel,
    pub(crate) rng: Mutex<SplitMix64>,
    pub(crate) find_iters: usize,
    pub(crate) warmup_iters: usize,
    /// Where the manifest + system dbs came from (reload re-reads here).
    artifacts_dir: PathBuf,
    /// Whether a missing manifest.json may fall back to the builtin
    /// synthetic manifest (interp handles only — see [`Handle::new`]).
    builtin_fallback: bool,
    /// Bumped by every successful reload; serve workers compare epochs
    /// to decide when to re-warm their private cache shards.
    reload_epoch: AtomicU64,
}

// Compile-time proof that a `&Handle` can cross threads (the serve
// engine's workers rely on this).
#[allow(dead_code)]
fn _assert_handle_send_sync() {
    fn is_send_sync<T: Send + Sync>() {}
    is_send_sync::<Handle>();
}

impl Handle {
    pub fn new(opts: HandleOptions) -> Result<Self> {
        let is_interp = matches!(&opts.backend, BackendChoice::Interp);
        let backend: Box<dyn Backend> = match opts.backend {
            #[cfg(feature = "pjrt")]
            BackendChoice::Cpu => Box::new(CpuBackend::new()?),
            BackendChoice::Interp => Box::new(InterpBackend::new()),
            BackendChoice::Mock(cfg) => Box::new(MockBackend::new(cfg)),
        };
        let dir = opts
            .artifacts_dir
            .unwrap_or_else(crate::testutil::artifacts_dir);

        let db_store = match opts.db_dir {
            Some(d) => DbStore::at(d),
            None => DbStore::user_default(),
        };
        // Degraded read-only serving: an explicit opt-in, the env flag
        // (absorbed by DbStore), or an unwritable db directory. The
        // short-circuit means an explicit flag never probes the dir.
        let read_only = opts.db_read_only
            || db_store.read_only()
            || !db_store.probe_writable();
        db_store.set_read_only(read_only);

        let (manifest, mut system_find, mut system_perf) =
            Self::load_artifact_set(&dir, is_interp)?;
        if read_only {
            (system_find, system_perf) =
                Self::overlay_embedded(system_find, system_perf);
        }

        // Loads work on a read-only store too — repairs are skipped.
        let user_find = db_store.load_find_db().unwrap_or_default();
        let user_perf = db_store.load_perf_db().unwrap_or_default();

        Ok(Self {
            backend,
            manifest: RwLock::new(Arc::new(manifest)),
            exec_cache: ExecCache::new(opts.exec_cache_capacity),
            disk_cache: DiskCache::new(),
            system_find: RwLock::new(Arc::new(system_find)),
            user_find: ShardedFindDb::with_db(user_find),
            system_perf: RwLock::new(Arc::new(system_perf)),
            user_perf: ShardedPerfDb::with_db(user_perf),
            db_store,
            model: GcnModel::default(),
            rng: Mutex::new(SplitMix64::new(opts.seed)),
            find_iters: opts.find_iters.max(1),
            warmup_iters: opts.warmup_iters,
            artifacts_dir: dir,
            builtin_fallback: is_interp,
            reload_epoch: AtomicU64::new(0),
        })
    }

    /// Read the manifest + system dbs for `dir`. The interp backend
    /// needs no artifact files: when the AOT set is absent it serves the
    /// builtin synthetic manifest (the same signatures aot.py emits). A
    /// present manifest.json still wins so interp handles can exercise
    /// real AOT'd shape metadata. System dbs ship next to the artifacts
    /// (produced by tuning runs / CI); user dbs shadow them.
    fn load_artifact_set(dir: &Path, builtin_fallback: bool)
        -> Result<(Manifest, FindDb, PerfDb)> {
        let manifest = if builtin_fallback
            && !dir.join("manifest.json").exists() {
            Manifest::builtin()
        } else {
            Manifest::load(dir)?
        };
        let system_store = DbStore::at(dir.join("system_db"));
        // The artifacts directory is never ours to repair or migrate —
        // system dbs are read in place, whatever their format vintage.
        system_store.set_read_only(true);
        let system_find = system_store.load_find_db().unwrap_or_default();
        let system_perf = system_store.load_perf_db().unwrap_or_default();
        Ok((manifest, system_find, system_perf))
    }

    /// Put the embedded compile-time db *under* the system dbs: real
    /// measurements from disk shadow the model-ranked embedded records,
    /// but every builtin signature keeps a servable ranking even when
    /// no db file is readable (the read-only degraded mode).
    fn overlay_embedded(system_find: FindDb, system_perf: PerfDb)
        -> (FindDb, PerfDb) {
        (embedded_find_db().merged_with(&system_find),
         embedded_perf_db().merged_with(&system_perf))
    }

    /// Convenience: mock-backed handle for tests (no PJRT, no artifacts
    /// needed beyond the manifest).
    pub fn mock_with_manifest(manifest: Manifest, cfg: MockConfig,
                              db_dir: PathBuf) -> Self {
        Self {
            backend: Box::new(MockBackend::new(cfg)),
            manifest: RwLock::new(Arc::new(manifest)),
            exec_cache: ExecCache::new(64),
            disk_cache: DiskCache::new(),
            system_find: RwLock::new(Arc::new(FindDb::default())),
            user_find: ShardedFindDb::new(),
            system_perf: RwLock::new(Arc::new(PerfDb::default())),
            user_perf: ShardedPerfDb::new(),
            db_store: DbStore::at(db_dir.clone()),
            model: GcnModel::default(),
            rng: Mutex::new(SplitMix64::new(7)),
            find_iters: 2,
            warmup_iters: 1,
            artifacts_dir: db_dir,
            builtin_fallback: false,
            reload_epoch: AtomicU64::new(0),
        }
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Snapshot of the current manifest. Cloning the `Arc` (not the
    /// manifest) keeps the view consistent across a whole operation even
    /// if a concurrent [`Handle::reload_artifacts`] swaps the shared one
    /// mid-flight. Bind it (`let m = handle.manifest();`) when artifact
    /// references must outlive one statement.
    pub fn manifest(&self) -> Arc<Manifest> {
        self.manifest.read().unwrap().clone()
    }

    /// Snapshot of the system find-db (reload-swappable like the
    /// manifest).
    pub(crate) fn system_find(&self) -> Arc<FindDb> {
        self.system_find.read().unwrap().clone()
    }

    /// Snapshot of the system perf-db.
    pub(crate) fn system_perf(&self) -> Arc<PerfDb> {
        self.system_perf.read().unwrap().clone()
    }

    /// How many successful [`Handle::reload_with`] /
    /// [`Handle::reload_artifacts`] swaps this handle has seen. Serve
    /// workers compare epochs to know when their warm shards went stale.
    pub fn reload_epoch(&self) -> u64 {
        self.reload_epoch.load(Ordering::Acquire)
    }

    /// Drop every compiled executable from the shared in-memory cache
    /// (reload invalidation; per-worker shards clear themselves).
    pub fn clear_exec_cache(&self) {
        self.exec_cache.clear();
    }

    /// Swap in a new manifest + system dbs without interrupting readers:
    /// in-flight operations keep the `Arc` snapshot they already hold,
    /// later calls see the new set. Invalidates the shared exec cache
    /// and bumps [`Handle::reload_epoch`]. This is the primitive under
    /// the serve engine's drain/reload path — the engine quiesces its
    /// workers first so no half-warmed batch mixes artifact sets.
    pub fn reload_with(&self, manifest: Manifest, system_find: FindDb,
                       system_perf: PerfDb) {
        {
            // fixed lock order (manifest → find → perf) so concurrent
            // reloaders can't deadlock; readers take one lock at a time
            let mut m = self.manifest.write().unwrap();
            let mut f = self.system_find.write().unwrap();
            let mut p = self.system_perf.write().unwrap();
            *m = Arc::new(manifest);
            *f = Arc::new(system_find);
            *p = Arc::new(system_perf);
        }
        self.exec_cache.clear();
        self.reload_epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Re-read the manifest and system dbs from the artifacts directory
    /// this handle was created over and [`Handle::reload_with`] them —
    /// the "a tuning run just refreshed the system dbs on disk" path.
    /// On error nothing is swapped.
    pub fn reload_artifacts(&self) -> Result<()> {
        let (m, mut f, mut p) = Self::load_artifact_set(
            &self.artifacts_dir, self.builtin_fallback)?;
        if self.db_read_only() {
            (f, p) = Self::overlay_embedded(f, p);
        }
        self.reload_with(m, f, p);
        Ok(())
    }

    pub fn perf_model(&self) -> &GcnModel {
        &self.model
    }

    /// The user db store (`save_dbs` persists here).
    pub fn db_store(&self) -> &DbStore {
        &self.db_store
    }

    /// Is this handle serving in degraded read-only db mode? (Explicit
    /// opt-in, `MIOPEN_RS_DB_READONLY=1`, or an unwritable db dir; the
    /// embedded db backs the find-db and saves are skipped.)
    pub fn db_read_only(&self) -> bool {
        self.db_store.read_only()
    }

    pub fn cache_stats(&self) -> (CacheStats, CacheStats) {
        (self.exec_cache.stats(), self.disk_cache.stats())
    }

    /// Compile (through both cache levels) the artifact with signature `sig`.
    pub fn compile_sig(&self, sig: &str) -> Result<Arc<dyn Executable>> {
        self.compile_sig_with(&self.exec_cache, sig)
    }

    /// Compile through a caller-owned exec-cache shard (the serve
    /// engine's workers each keep a private warm shard so the hot path
    /// never contends on the handle's shared cache lock).
    pub fn compile_sig_with(&self, cache: &ExecCache, sig: &str)
        -> Result<Arc<dyn Executable>> {
        let manifest = self.manifest();
        compile_cached(cache, &self.disk_cache, &manifest,
                       self.backend.as_ref(), sig)
    }

    /// Compile bypassing the in-memory cache (cold-path measurement for
    /// the cache ablation bench).
    pub fn compile_sig_cold(&self, sig: &str) -> Result<Arc<dyn Executable>> {
        let manifest = self.manifest();
        let path = self.disk_cache.lookup(&manifest, sig)?;
        let art = manifest.require(sig)?;
        self.backend.compile(&path, art)
    }

    /// Execute an artifact by signature with the given inputs.
    pub fn execute_sig(&self, sig: &str, inputs: &[HostTensor])
        -> Result<Vec<HostTensor>> {
        self.execute_sig_with(&self.exec_cache, sig, inputs)
    }

    /// Execute via a caller-owned exec-cache shard (shape-checked like
    /// [`Handle::execute_sig`]).
    pub fn execute_sig_with(&self, cache: &ExecCache, sig: &str,
                            inputs: &[HostTensor])
        -> Result<Vec<HostTensor>> {
        let manifest = self.manifest();
        let art = manifest.require(sig)?;
        if inputs.len() != art.inputs.len() {
            return Err(MiopenError::ShapeMismatch(format!(
                "{sig}: expected {} inputs, got {}",
                art.inputs.len(),
                inputs.len()
            )));
        }
        for (i, (t, spec)) in inputs.iter().zip(&art.inputs).enumerate() {
            if t.spec != *spec {
                return Err(MiopenError::ShapeMismatch(format!(
                    "{sig}: input {i} is {:?}/{}, expected {:?}/{}",
                    t.spec.shape, t.spec.dtype, spec.shape, spec.dtype
                )));
            }
        }
        self.compile_sig_with(cache, sig)?.run(inputs)
    }

    /// Generate manifest-conformant random inputs for an artifact (the
    /// find step's benchmark data).
    pub fn random_inputs(&self, sig: &str) -> Result<Vec<HostTensor>> {
        let manifest = self.manifest();
        let art = manifest.require(sig)?;
        let mut rng = self.rng.lock().unwrap();
        Ok(art
            .inputs
            .iter()
            .map(|spec| HostTensor::random_normal(spec, &mut rng))
            .collect())
    }

    /// Time one executable: `warmup_iters` untimed + `find_iters` timed
    /// runs, reporting the median (µs).
    pub fn time_exec(&self, exe: &Arc<dyn Executable>, inputs: &[HostTensor])
        -> Result<f64> {
        for _ in 0..self.warmup_iters {
            exe.run(inputs)?;
        }
        let mut times = Vec::with_capacity(self.find_iters);
        for _ in 0..self.find_iters {
            let t = Instant::now();
            exe.run(inputs)?;
            times.push(t.elapsed().as_secs_f64() * 1e6);
        }
        times.sort_by(f64::total_cmp);
        Ok(times[times.len() / 2])
    }

    /// Merged find-db view (user shadows system).
    pub fn find_db(&self) -> FindDb {
        self.system_find().merged_with(&self.user_find.snapshot())
    }

    /// Merged perf-db view.
    pub fn perf_db(&self) -> PerfDb {
        self.system_perf().merged_with(&self.user_perf.snapshot())
    }

    /// Persist the user dbs (find results + tuned params survive the
    /// process, §III-B "serialized to a designated directory"). Only
    /// the keys dirtied since the last save are journaled; a failed
    /// delta is re-marked dirty so the next save retries it — nothing
    /// is dropped between an error and the retry.
    pub fn save_dbs(&self) -> Result<()> {
        if let Some(delta) = self.user_find.take_dirty() {
            if let Err(e) = self.db_store.save_find_db(&delta) {
                self.user_find.mark_dirty(&delta);
                return Err(e);
            }
        }
        if let Some(delta) = self.user_perf.take_dirty() {
            if let Err(e) = self.db_store.save_perf_db(&delta) {
                self.user_perf.mark_dirty(&delta);
                return Err(e);
            }
        }
        Ok(())
    }
}
