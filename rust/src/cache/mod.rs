//! Two-level kernel cache (paper §III-C).
//!
//! MIOpen: "Once a kernel file is compiled, it is cached to disk ... The
//! specific kernel that would be invoked is loaded into memory ... and
//! stored in an in-memory cache for subsequent invocation."
//!
//! Our mapping (DESIGN.md §1):
//! - **Level 2 (disk)**: the `artifacts/` store of pre-lowered HLO text.
//!   [`DiskCache`] indexes it, verifies presence, and tracks how many
//!   expensive *lowerings* were avoided (a build-time artifact standing in
//!   for MIOpen's `.o` cache — PJRT-CPU executables are not serializable
//!   in xla_extension 0.5.1, so recompilation from HLO text on first touch
//!   is the honest analog of MIOpen's first-touch `clang` invocation).
//! - **Level 1 (memory)**: [`ExecCache`] holds compiled
//!   `PjRtLoadedExecutable`s keyed by full artifact signature with LRU
//!   eviction — the warm path after the warmup iteration the paper
//!   recommends.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;

use crate::manifest::Manifest;
use crate::runtime::{Backend, Executable};
use crate::types::{MiopenError, Result};

/// Hit/miss accounting (asserted by the cache ablation bench + tests).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

/// In-memory cache of compiled executables with LRU eviction.
pub struct ExecCache {
    capacity: usize,
    inner: RefCell<ExecCacheInner>,
}

struct ExecCacheInner {
    map: HashMap<String, (u64, Rc<dyn Executable>)>,
    tick: u64,
    stats: CacheStats,
}

impl ExecCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            capacity,
            inner: RefCell::new(ExecCacheInner {
                map: HashMap::new(),
                tick: 0,
                stats: CacheStats::default(),
            }),
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.borrow().stats.clone()
    }

    pub fn len(&self) -> usize {
        self.inner.borrow().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, sig: &str) -> bool {
        self.inner.borrow().map.contains_key(sig)
    }

    /// Get or compile-and-insert.
    pub fn get_or_compile(
        &self,
        sig: &str,
        compile: impl FnOnce() -> Result<Rc<dyn Executable>>,
    ) -> Result<Rc<dyn Executable>> {
        {
            let inner = &mut *self.inner.borrow_mut();
            inner.stats.lookups += 1;
            inner.tick += 1;
            let tick = inner.tick;
            if let Some((stamp, exe)) = inner.map.get_mut(sig) {
                *stamp = tick;
                inner.stats.hits += 1;
                return Ok(Rc::clone(exe));
            }
            inner.stats.misses += 1;
        }
        // compile outside the borrow (compile may be slow / reentrant)
        let exe = compile()?;
        let mut inner = self.inner.borrow_mut();
        if inner.map.len() >= self.capacity {
            if let Some(oldest) = inner
                .map
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(k, _)| k.clone())
            {
                inner.map.remove(&oldest);
                inner.stats.evictions += 1;
            }
        }
        let tick = inner.tick;
        inner.map.insert(sig.to_string(), (tick, Rc::clone(&exe)));
        Ok(exe)
    }

    pub fn invalidate(&self, sig: &str) {
        self.inner.borrow_mut().map.remove(sig);
    }

    pub fn clear(&self) {
        self.inner.borrow_mut().map.clear();
    }
}

/// Disk-level artifact index over the manifest directory.
pub struct DiskCache {
    stats: RefCell<CacheStats>,
}

impl DiskCache {
    pub fn new() -> Self {
        Self { stats: RefCell::new(CacheStats::default()) }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats.borrow().clone()
    }

    /// Resolve a signature to its on-disk HLO file, verifying existence.
    /// A hit means the expensive build-time lowering is avoided (the disk
    /// level of the paper's two caches). Synthetic manifests (the builtin
    /// interp set) have no files on disk, so the existence check is
    /// skipped — the interp backend never reads the path.
    pub fn lookup(&self, manifest: &Manifest, sig: &str) -> Result<PathBuf> {
        let mut stats = self.stats.borrow_mut();
        stats.lookups += 1;
        let art = manifest.get(sig).ok_or_else(|| {
            stats.misses += 1;
            MiopenError::ArtifactMissing(format!(
                "'{sig}' not in manifest — re-run `make artifacts`"))
        })?;
        let path = manifest.path_of(art);
        if !manifest.synthetic && !path.exists() {
            stats.misses += 1;
            return Err(MiopenError::ArtifactMissing(format!(
                "{} listed in manifest but missing on disk", path.display())));
        }
        stats.hits += 1;
        Ok(path)
    }
}

impl Default for DiskCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Compile through both cache levels: exec-cache hit → done; miss → disk
/// lookup → backend compile → insert.
pub fn compile_cached(
    exec_cache: &ExecCache,
    disk: &DiskCache,
    manifest: &Manifest,
    backend: &dyn Backend,
    sig: &str,
) -> Result<Rc<dyn Executable>> {
    exec_cache.get_or_compile(sig, || {
        let path = disk.lookup(manifest, sig)?;
        let art = manifest.require(sig)?;
        backend.compile(&path, art)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::TensorSpec;
    use crate::runtime::HostTensor;
    use crate::types::DType;

    struct NullExec;
    impl Executable for NullExec {
        fn run(&self, _: &[HostTensor]) -> Result<Vec<HostTensor>> {
            Ok(vec![])
        }
        fn output_arity(&self) -> usize {
            0
        }
    }

    fn compile_ok() -> Result<Rc<dyn Executable>> {
        Ok(Rc::new(NullExec))
    }

    #[test]
    fn hits_after_first_compile() {
        let cache = ExecCache::new(4);
        cache.get_or_compile("a", compile_ok).unwrap();
        cache.get_or_compile("a", || panic!("should not recompile")).unwrap();
        let s = cache.stats();
        assert_eq!(s.lookups, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let cache = ExecCache::new(2);
        cache.get_or_compile("a", compile_ok).unwrap();
        cache.get_or_compile("b", compile_ok).unwrap();
        cache.get_or_compile("a", compile_ok).unwrap(); // refresh a
        cache.get_or_compile("c", compile_ok).unwrap(); // evicts b
        assert!(cache.contains("a"));
        assert!(!cache.contains("b"));
        assert!(cache.contains("c"));
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn failed_compile_not_cached() {
        let cache = ExecCache::new(2);
        let r = cache.get_or_compile("x", || {
            Err(MiopenError::Runtime("boom".into()))
        });
        assert!(r.is_err());
        assert!(!cache.contains("x"));
        // retry succeeds and is cached
        cache.get_or_compile("x", compile_ok).unwrap();
        assert!(cache.contains("x"));
    }

    #[test]
    fn invalidate_and_clear() {
        let cache = ExecCache::new(4);
        cache.get_or_compile("a", compile_ok).unwrap();
        cache.invalidate("a");
        assert!(!cache.contains("a"));
        cache.get_or_compile("b", compile_ok).unwrap();
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn disk_cache_reports_missing_sig() {
        let m = Manifest::default();
        let d = DiskCache::new();
        assert!(d.lookup(&m, "nope").is_err());
        let s = d.stats();
        assert_eq!(s.lookups, 1);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn stats_invariant_hits_plus_misses_eq_lookups() {
        let cache = ExecCache::new(2);
        for sig in ["a", "b", "a", "c", "b", "a"] {
            let _ = cache.get_or_compile(sig, compile_ok);
        }
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, s.lookups);
        assert!(cache.len() <= 2);
    }

    #[allow(dead_code)]
    fn spec() -> TensorSpec {
        TensorSpec { shape: vec![1], dtype: DType::F32 }
    }
}
