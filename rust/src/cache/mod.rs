//! Two-level kernel cache (paper §III-C).
//!
//! MIOpen: "Once a kernel file is compiled, it is cached to disk ... The
//! specific kernel that would be invoked is loaded into memory ... and
//! stored in an in-memory cache for subsequent invocation."
//!
//! Our mapping (DESIGN.md §1):
//! - **Level 2 (disk)**: the `artifacts/` store of pre-lowered HLO text.
//!   [`DiskCache`] indexes it, verifies presence, and tracks how many
//!   expensive *lowerings* were avoided (a build-time artifact standing in
//!   for MIOpen's `.o` cache — PJRT-CPU executables are not serializable
//!   in xla_extension 0.5.1, so recompilation from HLO text on first touch
//!   is the honest analog of MIOpen's first-touch `clang` invocation).
//! - **Level 1 (memory)**: [`ExecCache`] holds compiled
//!   `PjRtLoadedExecutable`s keyed by full artifact signature with LRU
//!   eviction — the warm path after the warmup iteration the paper
//!   recommends.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::manifest::Manifest;
use crate::runtime::{Backend, Executable};
use crate::types::{MiopenError, Result};

/// Hit/miss accounting (asserted by the cache ablation bench + tests).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Accumulate another counter set (merging per-worker shard stats
    /// into the server's global view).
    pub fn merge(&mut self, other: &CacheStats) {
        self.lookups += other.lookups;
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
    }

    /// Hit fraction over all lookups (0.0 when never used).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups as f64
    }
}

/// In-memory cache of compiled executables with LRU eviction.
///
/// Thread-safe: the map lives behind a `Mutex` and executables are
/// `Arc`-shared, so one cache can serve concurrent workers — or each
/// worker can own a private shard (the serve engine does the latter to
/// keep its warm path contention-free).
pub struct ExecCache {
    capacity: usize,
    inner: Mutex<ExecCacheInner>,
}

struct ExecCacheInner {
    map: HashMap<String, (u64, Arc<dyn Executable>)>,
    tick: u64,
    stats: CacheStats,
}

impl ExecCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            capacity,
            inner: Mutex::new(ExecCacheInner {
                map: HashMap::new(),
                tick: 0,
                stats: CacheStats::default(),
            }),
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats.clone()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn contains(&self, sig: &str) -> bool {
        self.inner.lock().unwrap().map.contains_key(sig)
    }

    /// Get or compile-and-insert.
    pub fn get_or_compile(
        &self,
        sig: &str,
        compile: impl FnOnce() -> Result<Arc<dyn Executable>>,
    ) -> Result<Arc<dyn Executable>> {
        {
            let inner = &mut *self.inner.lock().unwrap();
            inner.stats.lookups += 1;
            inner.tick += 1;
            let tick = inner.tick;
            if let Some((stamp, exe)) = inner.map.get_mut(sig) {
                *stamp = tick;
                inner.stats.hits += 1;
                return Ok(Arc::clone(exe));
            }
            inner.stats.misses += 1;
        }
        // compile outside the lock (compile may be slow / reentrant);
        // concurrent misses on the same sig may compile twice — last
        // insert wins, both callers get a working executable.
        let exe = compile()?;
        let mut inner = self.inner.lock().unwrap();
        if inner.map.len() >= self.capacity
            && !inner.map.contains_key(sig) {
            if let Some(oldest) = inner
                .map
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(k, _)| k.clone())
            {
                inner.map.remove(&oldest);
                inner.stats.evictions += 1;
            }
        }
        let tick = inner.tick;
        inner.map.insert(sig.to_string(), (tick, Arc::clone(&exe)));
        Ok(exe)
    }

    pub fn invalidate(&self, sig: &str) {
        self.inner.lock().unwrap().map.remove(sig);
    }

    pub fn clear(&self) {
        self.inner.lock().unwrap().map.clear();
    }
}

/// Disk-level artifact index over the manifest directory.
pub struct DiskCache {
    stats: Mutex<CacheStats>,
}

impl DiskCache {
    pub fn new() -> Self {
        Self { stats: Mutex::new(CacheStats::default()) }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats.lock().unwrap().clone()
    }

    /// Resolve a signature to its on-disk HLO file, verifying existence.
    /// A hit means the expensive build-time lowering is avoided (the disk
    /// level of the paper's two caches). Synthetic manifests (the builtin
    /// interp set) have no files on disk, so the existence check is
    /// skipped — the interp backend never reads the path.
    pub fn lookup(&self, manifest: &Manifest, sig: &str) -> Result<PathBuf> {
        let mut stats = self.stats.lock().unwrap();
        stats.lookups += 1;
        let art = manifest.get(sig).ok_or_else(|| {
            stats.misses += 1;
            MiopenError::ArtifactMissing(format!(
                "'{sig}' not in manifest — re-run `make artifacts`"))
        })?;
        let path = manifest.path_of(art);
        if !manifest.synthetic && !path.exists() {
            stats.misses += 1;
            return Err(MiopenError::ArtifactMissing(format!(
                "{} listed in manifest but missing on disk", path.display())));
        }
        stats.hits += 1;
        Ok(path)
    }
}

impl Default for DiskCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Compile through both cache levels: exec-cache hit → done; miss → disk
/// lookup → backend compile → insert.
pub fn compile_cached(
    exec_cache: &ExecCache,
    disk: &DiskCache,
    manifest: &Manifest,
    backend: &dyn Backend,
    sig: &str,
) -> Result<Arc<dyn Executable>> {
    exec_cache.get_or_compile(sig, || {
        let path = disk.lookup(manifest, sig)?;
        let art = manifest.require(sig)?;
        backend.compile(&path, art)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::TensorSpec;
    use crate::runtime::HostTensor;
    use crate::types::DType;

    struct NullExec;
    impl Executable for NullExec {
        fn run(&self, _: &[HostTensor]) -> Result<Vec<HostTensor>> {
            Ok(vec![])
        }
        fn output_arity(&self) -> usize {
            0
        }
    }

    fn compile_ok() -> Result<Arc<dyn Executable>> {
        Ok(Arc::new(NullExec))
    }

    #[test]
    fn hits_after_first_compile() {
        let cache = ExecCache::new(4);
        cache.get_or_compile("a", compile_ok).unwrap();
        cache.get_or_compile("a", || panic!("should not recompile")).unwrap();
        let s = cache.stats();
        assert_eq!(s.lookups, 2);
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn lru_evicts_oldest() {
        let cache = ExecCache::new(2);
        cache.get_or_compile("a", compile_ok).unwrap();
        cache.get_or_compile("b", compile_ok).unwrap();
        cache.get_or_compile("a", compile_ok).unwrap(); // refresh a
        cache.get_or_compile("c", compile_ok).unwrap(); // evicts b
        assert!(cache.contains("a"));
        assert!(!cache.contains("b"));
        assert!(cache.contains("c"));
        assert_eq!(cache.stats().evictions, 1);
    }

    #[test]
    fn failed_compile_not_cached() {
        let cache = ExecCache::new(2);
        let r = cache.get_or_compile("x", || {
            Err(MiopenError::Runtime("boom".into()))
        });
        assert!(r.is_err());
        assert!(!cache.contains("x"));
        // retry succeeds and is cached
        cache.get_or_compile("x", compile_ok).unwrap();
        assert!(cache.contains("x"));
    }

    #[test]
    fn invalidate_and_clear() {
        let cache = ExecCache::new(4);
        cache.get_or_compile("a", compile_ok).unwrap();
        cache.invalidate("a");
        assert!(!cache.contains("a"));
        cache.get_or_compile("b", compile_ok).unwrap();
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn disk_cache_reports_missing_sig() {
        let m = Manifest::default();
        let d = DiskCache::new();
        assert!(d.lookup(&m, "nope").is_err());
        let s = d.stats();
        assert_eq!(s.lookups, 1);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn stats_invariant_hits_plus_misses_eq_lookups() {
        let cache = ExecCache::new(2);
        for sig in ["a", "b", "a", "c", "b", "a"] {
            let _ = cache.get_or_compile(sig, compile_ok);
        }
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, s.lookups);
        assert!(cache.len() <= 2);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let cache = Arc::new(ExecCache::new(8));
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let c = Arc::clone(&cache);
            joins.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    c.get_or_compile(&format!("sig{}", (i + t) % 6),
                                     compile_ok)
                        .unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let s = cache.stats();
        assert_eq!(s.lookups, 200);
        assert_eq!(s.hits + s.misses, s.lookups);
        assert!(cache.len() <= 8);
    }

    #[test]
    fn stats_merge_and_hit_rate() {
        let mut a = CacheStats { lookups: 4, hits: 3, misses: 1,
                                 evictions: 0 };
        let b = CacheStats { lookups: 6, hits: 3, misses: 3, evictions: 2 };
        a.merge(&b);
        assert_eq!(a.lookups, 10);
        assert_eq!(a.hits, 6);
        assert_eq!(a.evictions, 2);
        assert!((a.hit_rate() - 0.6).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[allow(dead_code)]
    fn spec() -> TensorSpec {
        TensorSpec { shape: vec![1], dtype: DType::F32 }
    }
}
