//! Quickstart: the MIOpen workflow in five steps (paper §IV-A).
//!
//! 1. create a handle   2. describe the problem   3. run the find step
//! 4. execute with the best algorithm   5. reuse the memoized result.
//!
//! Run: `cargo run --release --example quickstart`

use miopen_rs::prelude::*;
use miopen_rs::primitives::conv;
use miopen_rs::runtime::HostTensor;
use miopen_rs::util::rng::SplitMix64;

fn main() -> Result<()> {
    // 1. the handle owns the PJRT backend, caches and databases
    let handle = Handle::new(Default::default())?;
    println!("platform: {}\n", handle.platform());

    // 2. a GoogLeNet-style 3x3 convolution (Figure 6 config)
    let x_desc = TensorDesc::nchw(4, 16, 28, 28, DType::F32);
    let w_desc = FilterDesc::kcrs(32, 16, 3, 3, DType::F32);
    let conv_desc = ConvDesc::simple(1, 1);
    let problem = ConvProblem::forward(x_desc, w_desc, conv_desc);

    // 3. the find step benchmarks every applicable solver
    println!("find step (first call benchmarks all solvers):");
    let results = handle.find_convolution(&problem)?;
    println!("{:<10} {:>12} {:>14} {:>12}", "algo", "measured_us",
             "gcn_model_us", "workspace");
    for r in &results {
        println!("{:<10} {:>12.1} {:>14.1} {:>12}", r.algo, r.time_us,
                 r.modeled_time_us, r.workspace_bytes);
    }

    // 4. execute with the winner
    let mut rng = SplitMix64::new(1);
    let x = HostTensor::random_normal(
        &miopen_rs::manifest::TensorSpec {
            shape: vec![4, 16, 28, 28],
            dtype: DType::F32,
        },
        &mut rng,
    );
    let w = HostTensor::random_normal(
        &miopen_rs::manifest::TensorSpec {
            shape: vec![32, 16, 3, 3],
            dtype: DType::F32,
        },
        &mut rng,
    );
    let best = &results[0].algo;
    let y = conv::forward_with_algo(&handle, best, &x, &w, &conv_desc)?;
    println!("\nexecuted '{best}': output {:?}, first values {:?}",
             y.spec.shape,
             &y.as_f32()?[..4]);

    // 5. second find call hits the find-db — no benchmarking
    let again = handle.find_convolution(&problem)?;
    println!("\nmemoized find returned {} algos instantly (best: {})",
             again.len(), again[0].algo);

    // persist the dbs so the NEXT PROCESS skips the find step too
    handle.save_dbs()?;
    let (exec, disk) = handle.cache_stats();
    println!("\nexec cache: {} lookups, {} hits", exec.lookups, exec.hits);
    println!("disk cache: {} lookups, {} hits", disk.lookups, disk.hits);
    Ok(())
}
