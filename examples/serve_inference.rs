//! Batched inference serving (DESIGN.md e2e-serve): a trained-architecture
//! CNN served under Poisson load through the dynamic batcher, reporting
//! the latency distribution and throughput.
//!
//! Run: `cargo run --release --example serve_inference -- [requests] [rate]`

use std::sync::mpsc;
use std::time::Duration;

use miopen_rs::handle::Handle;
use miopen_rs::serve::{generate_load, run_server, ServeConfig};
use miopen_rs::types::Result;

fn main() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(256);
    let rate: f64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(400.0);

    let handle = Handle::new(Default::default())?;
    let infer = handle.manifest().require("cnn_infer-f32")?;
    let image_elems: usize =
        infer.inputs.last().unwrap().shape[1..].iter().product();

    println!("# e2e-serve: {n} requests, Poisson rate {rate}/s, \
              batch<=16, 5ms batching window");

    // §III-C warmup: compile + run the model once so the two-level cache
    // is hot BEFORE traffic arrives — otherwise the first batching window
    // absorbs the PJRT compile and every early request pays it.
    {
        let mut warm = handle.execute_sig("cnn_init-f32", &[])?;
        let x = miopen_rs::runtime::HostTensor::zeros(
            infer.inputs.last().unwrap());
        warm.push(x);
        handle.execute_sig("cnn_infer-f32", &warm)?;
    }

    for (label, cfg) in [
        ("batched (dynamic batcher)",
         ServeConfig { batch_max: 16,
                       batch_timeout: Duration::from_millis(5),
                       ..Default::default() }),
        ("unbatched (batch_max=1)",
         ServeConfig { batch_max: 1,
                       batch_timeout: Duration::from_millis(0),
                       ..Default::default() }),
        ("batched, 4 workers",
         ServeConfig { batch_max: 16,
                       batch_timeout: Duration::from_millis(5),
                       workers: 4,
                       ..Default::default() }),
    ] {
        let (tx, rx) = mpsc::channel();
        let loader = std::thread::spawn(move || {
            generate_load(&tx, n, rate, image_elems, 42)
        });
        let stats = run_server(&handle, &cfg, rx)?;
        let responses = loader.join().expect("loader");
        let served = responses.iter().count();

        println!("\n== {label} ==");
        println!("served:          {served}/{n}");
        println!("latency:         {}", stats.latency.summary());
        println!("mean batch size: {:.2}", stats.throughput.mean_batch_size());
        println!("throughput:      {:.1} req/s", stats.throughput.req_per_s());
        println!("shard cache:     {:.0}% hits",
                 stats.shard_cache.hit_rate() * 100.0);
    }

    println!("\nNOTE: batching amortizes the fixed per-execution cost over \
              up to 16 requests — the same launch-overhead argument as the \
              paper's Fusion API, applied at the serving layer. Worker \
              threads then scale that across cores (see `serve-bench`).");
    Ok(())
}
