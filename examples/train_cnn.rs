//! E2E VALIDATION (EXPERIMENTS.md e2e-train): train a small CNN for a few
//! hundred steps on a synthetic 3-class image corpus and log the loss
//! curve. Every layer of the stack is exercised:
//!
//!   L1  Pallas kernels (direct conv fwd/bwd-data/bwd-weights, batchnorm
//!       train/bwd, maxpool fwd/bwd, relu, GEMM, log-softmax) —
//!   L2  the JAX train-step graph wiring them through custom_vjp, lowered
//!       once to `cnn_train-f32.hlo.txt` —
//!   L3  this Rust driver: data generation, the step loop, loss logging
//!       and evaluation, all through the PJRT runtime. No Python runs.
//!
//! Run: `cargo run --release --example train_cnn -- [steps]`

use std::time::Instant;

use miopen_rs::handle::Handle;
use miopen_rs::runtime::HostTensor;
use miopen_rs::types::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let handle = Handle::new(Default::default())?;

    println!("# e2e-train: tiny CNN, {steps} steps, batch 16, lr 0.05");
    println!("# model: conv3x3(3->8) - BN - relu - maxpool - conv3x3(8->16)");
    println!("#        - BN - relu - maxpool - dense(256->3), all on L1 kernels");

    let mut params = handle.execute_sig("cnn_init-f32", &[])?;
    let t0 = Instant::now();
    let mut curve: Vec<(usize, f32)> = Vec::new();

    for step in 0..steps {
        let seed = HostTensor::from_u32(&[2], &[step as u32, 0xDA7A]);
        let batch = handle.execute_sig("cnn_datagen-f32", &[seed])?;
        let mut inputs = params.clone();
        inputs.extend(batch);
        let mut out = handle.execute_sig("cnn_train-f32", &inputs)?;
        let loss = out.pop().unwrap().scalar_f32()?;
        params = out;
        if step % 10 == 0 || step == steps - 1 {
            println!("step {step:4}  loss {loss:.4}");
            curve.push((step, loss));
        }
    }
    let train_s = t0.elapsed().as_secs_f64();

    // held-out evaluation
    let mut correct = 0usize;
    let mut total = 0usize;
    for eval in 0..8u32 {
        let seed = HostTensor::from_u32(&[2], &[100_000 + eval, 0xE7A1]);
        let batch = handle.execute_sig("cnn_datagen-f32", &[seed])?;
        let labels = batch[1].as_i32()?;
        let mut inputs = params.clone();
        inputs.push(batch[0].clone());
        let out = handle.execute_sig("cnn_infer-f32", &inputs)?;
        let preds = out[1].as_i32()?;
        correct += preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
        total += labels.len();
    }

    let first = curve.first().map(|c| c.1).unwrap_or(f32::NAN);
    let last = curve.last().map(|c| c.1).unwrap_or(f32::NAN);
    println!("\n# summary");
    println!("loss: {first:.4} -> {last:.4} over {steps} steps");
    println!("held-out accuracy: {:.1}% ({correct}/{total})",
             100.0 * correct as f64 / total as f64);
    println!("wall time: {train_s:.1}s ({:.1} steps/s)",
             steps as f64 / train_s);
    let (exec, _) = handle.cache_stats();
    println!("exec cache: {} lookups, {} hits (3 artifacts compiled once)",
             exec.lookups, exec.hits);

    assert!(last < first * 0.5, "loss must at least halve");
    println!("\nE2E OK: loss decreased and all three layers composed.");
    Ok(())
}
