//! RNN workload (paper §IV-C): run the fused-GEMM LSTM over a sequence
//! batch and compare against the naive per-gate formulation — the
//! measured version of equations 11–12's claimed savings.
//!
//! Run: `cargo run --release --example rnn_seq`

use std::time::Instant;

use miopen_rs::handle::Handle;
use miopen_rs::types::Result;
use miopen_rs::util::rng::SplitMix64;
use miopen_rs::runtime::HostTensor;

fn time_sig(handle: &Handle, sig: &str, iters: usize) -> Result<(f64, Vec<f32>)> {
    let art = handle.manifest().require(sig)?;
    let mut rng = SplitMix64::new(3);
    let inputs: Vec<HostTensor> = art
        .inputs
        .iter()
        .map(|s| HostTensor::random_normal(s, &mut rng))
        .collect();
    let exe = handle.compile_sig(sig)?;
    exe.run(&inputs)?; // warmup
    let t = Instant::now();
    let mut out = Vec::new();
    for _ in 0..iters {
        out = exe.run(&inputs)?;
    }
    Ok((t.elapsed().as_secs_f64() * 1e6 / iters as f64,
        out[0].as_f32()?))
}

fn main() -> Result<()> {
    let handle = Handle::new(Default::default())?;

    println!("# LSTM fused-GEMM (eqs. 11-12) vs naive per-gate formulation");
    println!("{:<6} {:>12} {:>12} {:>9}", "T", "fused_us", "naive_us",
             "speedup");
    for t in [4, 8, 16, 32] {
        let fused_sig = format!("rnn-lstm-fused-t{t}b8x32h32-f32");
        let naive_sig = format!("rnn-lstm-naive-t{t}b8x32h32-f32");
        let (fused_us, hf) = time_sig(&handle, &fused_sig, 5)?;
        let (naive_us, hn) = time_sig(&handle, &naive_sig, 5)?;
        // same inputs seed -> outputs must agree
        let max_err = hf
            .iter()
            .zip(&hn)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-3, "fused/naive disagree: {max_err}");
        println!("{:<6} {:>12.1} {:>12.1} {:>8.2}x", t, fused_us, naive_us,
                 naive_us / fused_us);
    }

    println!("\n# bidirectional LSTM (miopenRNNbidirection)");
    let (us, h) = time_sig(&handle, "rnn-lstm-bidir-t16b8x32h32-f32", 3)?;
    println!("T=16 B=8 H=32x2: {us:.1}us, output len {}", h.len());

    println!("\n# GRU + vanilla cells");
    for sig in ["rnn-gru-fused-t16b8x32h32-f32",
                "rnn-vanilla-fused-t16b8x32h32-f32"] {
        let (us, _) = time_sig(&handle, sig, 3)?;
        println!("{sig}: {us:.1}us");
    }

    println!("\n# length-descending batch rule (paper §IV-C)");
    use miopen_rs::descriptors::RnnDesc;
    println!("batches [8,8,4,2] -> {:?}",
             RnnDesc::validate_batch_layout(&[8, 8, 4, 2]).is_ok());
    println!("batches [4,8]     -> {:?} (rejected: would need T+1 GEMMs)",
             RnnDesc::validate_batch_layout(&[4, 8]).is_ok());
    Ok(())
}
