//! Auto-tuning session (paper §III-B): race the direct solver's block_k
//! grid on a problem, persist the winner in the user perf-db, and show the
//! find step picking the tuned variant afterwards.
//!
//! Run: `cargo run --release --example tune_conv`

use miopen_rs::descriptors::{ConvDesc, FilterDesc, TensorDesc};
use miopen_rs::find::{ConvProblem, FindOptions};
use miopen_rs::handle::Handle;
use miopen_rs::prelude::DType;
use miopen_rs::tuning::{format_params, TuneOptions, TuningSession};
use miopen_rs::types::Result;

fn main() -> Result<()> {
    let handle = Handle::new(Default::default())?;

    // TUNE_CONFIGS[0]: block_k variants {4, 8, 16, 32} were AOT'd
    let problem = ConvProblem::forward(
        TensorDesc::nchw(4, 16, 28, 28, DType::F32),
        FilterDesc::kcrs(32, 16, 3, 3, DType::F32),
        ConvDesc::simple(1, 1),
    );
    println!("tuning {}", problem.sig()?.db_key());

    println!("\n== full grid ==");
    let results = TuningSession::new(&handle).tune_convolution(&problem)?;
    for r in &results {
        println!("solver {}", r.solver);
        for (params, us) in &r.evaluated {
            let marker = if *params == r.best_params { "  <-- best" } else { "" };
            println!("  [{}] {:>10.1}us{}", format_params(params), us, marker);
        }
        if let Some(sp) = r.speedup_vs_default() {
            println!("  speedup vs shipped default: {sp:.2}x");
        }
    }

    println!("\n== pruned search (keep 2, paper's pruned-space approach) ==");
    let pruned = TuningSession::with_options(&handle,
                                             TuneOptions { prune_keep: 2 })
        .tune_convolution(&problem)?;
    for r in &pruned {
        println!("solver {}: evaluated {} points ({} pruned away), best [{}]",
                 r.solver, r.evaluated.len(), r.pruned_out,
                 format_params(&r.best_params));
    }

    println!("\n== find step after tuning (uses the tuned variant) ==");
    let found = handle.find_convolution_opt(
        &problem,
        &FindOptions { exhaustive: true, rank_by_model: false },
    )?;
    for f in &found {
        println!("{:<10} {:>10.1}us  artifact {}", f.algo, f.time_us,
                 f.artifact_sig);
    }

    handle.save_dbs()?;
    println!("\nperf-db + find-db persisted (future processes skip both \
              the grid race and the find benchmarking).");
    Ok(())
}
