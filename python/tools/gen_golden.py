"""Generate golden parity fixtures from the JAX reference kernels.

Writes rust/tests/fixtures/golden.json: small deterministic input tensors
plus the outputs of python/compile/kernels/ref.py on them. The Rust interp
backend's reference kernels (rust/src/runtime/interp/kernels.rs) must
reproduce every case within 1e-4 relative error — asserted by
rust/tests/golden_parity.rs, which runs hermetically against the checked-in
JSON (this script only needs to re-run when the reference semantics
change).

Run:  cd python && python -m tools.gen_golden
"""

from __future__ import annotations

import json
import os

import numpy as np

from compile.kernels import ref

RNG = np.random.default_rng(20260801)


def t(a):
    a = np.asarray(a, np.float32)
    return {"shape": list(a.shape), "data": [float(v) for v in a.reshape(-1)]}


def rand(*shape):
    return np.asarray(RNG.standard_normal(shape), np.float32)


CASES = []


def case(name, kind, params, inputs, outputs):
    outs = outputs if isinstance(outputs, (tuple, list)) else (outputs,)
    CASES.append({
        "name": name,
        "kind": kind,
        "params": params,
        "inputs": [t(a) for a in inputs],
        "outputs": [t(np.asarray(o)) for o in outs],
    })
    print(f"  {name}: {sum(int(np.asarray(o).size) for o in outs)} output elems")


def conv_params(n, c, h, w, k, r, s, u=1, v=1, p=0, q=0, l=1, j=1, g=1):
    return dict(n=n, c=c, h=h, w=w, k=k, r=r, s=s, u=u, v=v, p=p, q=q,
                l=l, j=j, g=g)


def gen_conv():
    # dense 3x3 stride 1 pad 1
    x, w = rand(2, 3, 6, 6), rand(4, 3, 3, 3)
    case("conv_fwd_3x3_s1_p1", "conv_fwd",
         conv_params(2, 3, 6, 6, 4, 3, 3, p=1, q=1), [x, w],
         ref.conv2d_fwd(x, w, stride=(1, 1), pad=(1, 1)))
    # strided
    case("conv_fwd_3x3_s2_p1", "conv_fwd",
         conv_params(2, 3, 6, 6, 4, 3, 3, u=2, v=2, p=1, q=1), [x, w],
         ref.conv2d_fwd(x, w, stride=(2, 2), pad=(1, 1)))
    # dilated
    case("conv_fwd_3x3_dil2_p2", "conv_fwd",
         conv_params(2, 3, 6, 6, 4, 3, 3, p=2, q=2, l=2, j=2), [x, w],
         ref.conv2d_fwd(x, w, stride=(1, 1), pad=(2, 2), dilation=(2, 2)))
    # grouped
    xg, wg = rand(2, 4, 6, 6), rand(4, 2, 3, 3)
    case("conv_fwd_3x3_g2", "conv_fwd",
         conv_params(2, 4, 6, 6, 4, 3, 3, p=1, q=1, g=2), [xg, wg],
         ref.conv2d_fwd(xg, wg, stride=(1, 1), pad=(1, 1), groups=2))
    # im2col+GEMM path, 5x5
    x5, w5 = rand(1, 2, 8, 8), rand(3, 2, 5, 5)
    case("conv_gemm_5x5_p2", "conv_gemm",
         conv_params(1, 2, 8, 8, 3, 5, 5, p=2, q=2), [x5, w5],
         ref.conv2d_im2col_gemm(x5, w5, stride=(1, 1), pad=(2, 2)))
    # backward data / weights (stride 1 and 2)
    dy = rand(2, 4, 6, 6)
    case("conv_bwd_data_3x3_s1_p1", "conv_bwd_data",
         conv_params(2, 3, 6, 6, 4, 3, 3, p=1, q=1), [dy, w],
         ref.conv2d_bwd_data(dy, w, (2, 3, 6, 6), stride=(1, 1), pad=(1, 1)))
    case("conv_bwd_weights_3x3_s1_p1", "conv_bwd_weights",
         conv_params(2, 3, 6, 6, 4, 3, 3, p=1, q=1), [dy, x],
         ref.conv2d_bwd_weights(dy, x, (4, 3, 3, 3), stride=(1, 1),
                                pad=(1, 1)))
    dy2 = rand(2, 4, 3, 3)
    case("conv_bwd_data_3x3_s2_p1", "conv_bwd_data",
         conv_params(2, 3, 6, 6, 4, 3, 3, u=2, v=2, p=1, q=1), [dy2, w],
         ref.conv2d_bwd_data(dy2, w, (2, 3, 6, 6), stride=(2, 2), pad=(1, 1)))
    case("conv_bwd_weights_3x3_s2_p1", "conv_bwd_weights",
         conv_params(2, 3, 6, 6, 4, 3, 3, u=2, v=2, p=1, q=1), [dy2, x],
         ref.conv2d_bwd_weights(dy2, x, (4, 3, 3, 3), stride=(2, 2),
                                pad=(1, 1)))


def pool_params(n, c, h, w, wh, ww, u, v, p, q):
    return dict(n=n, c=c, h=h, w=w, wh=wh, ww=ww, u=u, v=v, p=p, q=q)


def gen_pool():
    x = rand(1, 2, 6, 6)
    for mode in ("max", "avg"):
        y = ref.pool2d_fwd(x, window=(2, 2), stride=(2, 2), pad=(0, 0),
                           mode=mode)
        case(f"pool_fwd_{mode}_2x2_s2", f"pool_fwd_{mode}",
             pool_params(1, 2, 6, 6, 2, 2, 2, 2, 0, 0), [x], y)
        dy = rand(*np.asarray(y).shape)
        case(f"pool_bwd_{mode}_2x2_s2", f"pool_bwd_{mode}",
             pool_params(1, 2, 6, 6, 2, 2, 2, 2, 0, 0), [x, dy],
             ref.pool2d_bwd(x, dy, window=(2, 2), stride=(2, 2), pad=(0, 0),
                            mode=mode))
    # padded 3x3 window, stride 2
    y = ref.pool2d_fwd(x, window=(3, 3), stride=(2, 2), pad=(1, 1),
                       mode="max")
    case("pool_fwd_max_3x3_s2_p1", "pool_fwd_max",
         pool_params(1, 2, 6, 6, 3, 3, 2, 2, 1, 1), [x], y)


def gen_bn():
    n, c, h, w = 2, 3, 4, 4
    params = dict(n=n, c=c, h=h, w=w)
    x = rand(n, c, h, w)
    gamma, beta = rand(c), rand(c)
    y, mu, var = ref.batchnorm_spatial_fwd_train(x, gamma, beta)
    case("bn_spatial_train", "bn_spatial_train", params, [x, gamma, beta],
         (y, mu, var))
    mean_i = rand(c)
    var_i = np.abs(rand(c)) + 0.1
    case("bn_spatial_infer", "bn_spatial_infer", params,
         [x, gamma, beta, mean_i, var_i],
         ref.batchnorm_spatial_fwd_infer(x, gamma, beta, mean_i, var_i))
    dy = rand(n, c, h, w)
    dx, dg, db = ref.batchnorm_spatial_bwd(x, dy, gamma, np.asarray(mu),
                                           np.asarray(var))
    case("bn_spatial_bwd", "bn_spatial_bwd", params,
         [x, dy, gamma, np.asarray(mu), np.asarray(var)], (dx, dg, db))
    gp, bp = rand(c, h, w), rand(c, h, w)
    yp, mup, varp = ref.batchnorm_peract_fwd_train(x, gp, bp)
    case("bn_peract_train", "bn_peract_train", params, [x, gp, bp],
         (yp, mup, varp))


def gen_softmax():
    n, c, h, w = 2, 5, 2, 2
    params = dict(n=n, c=c, h=h, w=w)
    x = rand(n, c, h, w)
    for log in (False, True):
        nm = "log_softmax" if log else "softmax"
        y = ref.softmax_fwd(x, log=log)
        case(f"{nm}_fwd", f"{nm}_fwd", params, [x], y)
        dy = rand(n, c, h, w)
        case(f"{nm}_bwd", f"{nm}_bwd", params, [np.asarray(y), dy],
             ref.softmax_bwd(np.asarray(y), dy, log=log))


def gen_act():
    shape = (2, 3, 4)
    params = {}
    x = rand(*shape)
    alphas = {"leaky_relu": 0.01, "elu": 1.0, "clipped_relu": 6.0}
    for mode in ("relu", "leaky_relu", "tanh", "sigmoid", "elu",
                 "clipped_relu", "abs", "identity"):
        a = alphas.get(mode, 0.0)
        case(f"act_fwd_{mode}", f"act_fwd_{mode}", params, [x],
             ref.activation_fwd(x, mode, a))
    dy = rand(*shape)
    for mode in ("relu", "tanh", "sigmoid", "elu"):
        a = alphas.get(mode, 0.0)
        case(f"act_bwd_{mode}", f"act_bwd_{mode}", params, [x, dy],
             ref.activation_bwd(x, dy, mode, a))


def gen_fused():
    x, w, b = rand(1, 3, 5, 5), rand(4, 3, 3, 3), rand(4)
    case("fused_cba_relu", "cba_relu",
         conv_params(1, 3, 5, 5, 4, 3, 3, p=1, q=1), [x, w, b],
         ref.fused_conv_bias_act_ref(x, w, b, stride=(1, 1), pad=(1, 1),
                                     mode="relu"))


def main():
    print("generating golden fixtures ...")
    gen_conv()
    gen_pool()
    gen_bn()
    gen_softmax()
    gen_act()
    gen_fused()
    out_dir = os.path.join(os.path.dirname(__file__), "..", "..", "rust",
                           "tests", "fixtures")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "golden.json")
    with open(path, "w") as f:
        json.dump({"version": 1, "tolerance": 1e-4, "cases": CASES}, f)
    print(f"wrote {len(CASES)} cases to {os.path.normpath(path)} "
          f"({os.path.getsize(path)} bytes)")


if __name__ == "__main__":
    main()
