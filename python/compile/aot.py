"""AOT artifact generator: lower every (primitive, algorithm, config,
dtype, direction, tuning-variant) to HLO **text** + write manifest.json.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md). Everything is lowered with return_tuple=True
and unwrapped with to_tupleN() on the Rust side.

Run via `make artifacts`:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model
from .kernels import (activations, batchnorm, ctc, direct, fft_conv, fused,
                      gemm, im2col_gemm, implicit_gemm, lrn, pooling,
                      rnn_cells, softmax, tensor_ops, winograd)

DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16, "f16": jnp.float16,
          "i32": jnp.int32, "u32": jnp.uint32, "i8": jnp.int8}
DTYPE_NAMES = {v: k for k, v in DTYPES.items()}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True is load-bearing: the default ELIDES big
    # constant tensors as `constant({...})`, which the HLO parser then
    # silently reads back as zeros — corrupting e.g. Winograd transform
    # tables and the seeded CNN init.
    return comp.as_hlo_text(print_large_constants=True)


def spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), DTYPES[dtype])


class Emitter:
    def __init__(self, out_dir, force=False, only=None):
        self.out_dir = out_dir
        self.force = force
        self.only = only
        self.manifest = []
        self.count = 0
        self.skipped = 0

    def emit(self, sig, fn, in_specs, *, primitive, algo="", direction="",
             dtype="f32", tags=(), params=None, workspace_bytes=0,
             tuning=None):
        if self.only and self.only not in sig:
            return
        for e in self.manifest:
            if e["sig"] == sig:
                # dedupe (configs can overlap across sets) but merge tags
                # so every experiment set still finds its artifacts
                e["tags"] = sorted(set(e["tags"]) | set(tags))
                return
        path = os.path.join(self.out_dir, f"{sig}.hlo.txt")
        out_avals = jax.eval_shape(fn, *in_specs)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        if self.force or not os.path.exists(path):
            # lowering is the expensive step — only done when (re)writing
            text = to_hlo_text(jax.jit(fn).lower(*in_specs))
            with open(path, "w") as f:
                f.write(text)
            self.count += 1
        else:
            self.skipped += 1
        self.manifest.append({
            "sig": sig,
            "file": f"{sig}.hlo.txt",
            "primitive": primitive,
            "algo": algo,
            "direction": direction,
            "dtype": dtype,
            "tags": list(tags),
            "params": params or {},
            "inputs": [{"shape": list(s.shape),
                        "dtype": DTYPE_NAMES[s.dtype.type
                                             if hasattr(s.dtype, "type")
                                             else s.dtype]}
                       for s in [jax.ShapeDtypeStruct(a.shape, a.dtype)
                                 for a in in_specs]],
            "outputs": [{"shape": list(a.shape),
                         "dtype": DTYPE_NAMES[a.dtype.type
                                              if hasattr(a.dtype, "type")
                                              else a.dtype]}
                        for a in out_avals],
            "workspace_bytes": int(workspace_bytes),
            "tuning": tuning or {},
        })
        if (self.count + self.skipped) % 25 == 0:
            print(f"  ... {self.count} lowered, {self.skipped} kept",
                  flush=True)

    def write_manifest(self):
        if self.only:
            print(f"--only {self.only}: {self.count} lowered; manifest NOT "
                  "rewritten (partial run)")
            return
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump({"version": 1, "artifacts": self.manifest}, f, indent=1)
        print(f"manifest: {len(self.manifest)} artifacts "
              f"({self.count} lowered, {self.skipped} reused)")


# ---------------------------------------------------------------------------
# Convolution artifacts
# ---------------------------------------------------------------------------


def conv_sig(direction, algo, cc, dtype, bk=None, wt=None, gt=None,
             layout="nchw"):
    """Artifact signature; bk = direct block_k tile, wt = winograd
    transform-domain threads, gt = blocked-GEMM tile-grid index (typed
    TuneTag suffixes on the Rust side). NCHW emits no layout segment —
    legacy signatures stay byte-identical — while NHWC appends `-nhwc`
    after the dtype, before any tuning suffix."""
    t = ""
    if bk is not None:
        t = f"-bk{bk}"
    elif wt is not None:
        t = f"-wt{wt}"
    elif gt is not None:
        t = f"-gt{gt}"
    lt = "-nhwc" if layout == "nhwc" else ""
    return f"conv_{direction}-{algo}-{cc.sig_params()}-{dtype}{lt}{t}"


def fwd_algos(cc):
    """Applicable forward algorithms for a config (mirrors rust solvers)."""
    algos = ["gemm", "direct", "implicit"]
    if cc.g == cc.c and cc.g > 1:
        # depthwise proper: the dedicated solver outranks the grouped
        # direct fallback it replaced
        algos.insert(0, "depthwise")
    if (cc.r, cc.s) == (3, 3) and (cc.u, cc.v) == (1, 1) \
            and (cc.l, cc.j) == (1, 1) and cc.g == 1:
        algos.append("winograd")
    if max(cc.r, cc.s) >= 5 and (cc.l, cc.j) == (1, 1) and cc.g == 1:
        algos.append("fft")
    return algos


def bwd_algos(cc):
    algos = ["gemm", "direct"]
    # winograd bwd-data rides the forward pipeline via the adjoint
    # identity (mirrored padding 2 - p), which needs pad <= 2
    if (cc.r, cc.s) == (3, 3) and (cc.u, cc.v) == (1, 1) \
            and (cc.l, cc.j) == (1, 1) and cc.g == 1 \
            and cc.p <= 2 and cc.q <= 2:
        algos.append("winograd")
    return algos


def nhwc_wrap(fn):
    """Lift a binary NCHW conv lambda to channels-last buffers: transpose
    the operands at the boundary, run the NCHW lowering, transpose the
    results back. Input tensors are NHWC / KRSC ((0,3,1,2) to NCHW /
    KCRS); outputs invert with (0,2,3,1) — the same permutation pair for
    fwd (y), bwd (dx) and wrw (dw, KCRS back to KRSC). The Rust interp
    backend runs native channels-last kernels instead; here the lowered
    HLO carries the boundary transposes, which is what the per-layout
    workspace accounting charges for."""
    return lambda a, b: tuple(
        jnp.transpose(o, (0, 2, 3, 1))
        for o in fn(jnp.transpose(a, (0, 3, 1, 2)),
                    jnp.transpose(b, (0, 3, 1, 2))))


def make_conv_fn(direction, algo, cc, bk=16):
    stride, pad, dil = (cc.u, cc.v), (cc.p, cc.q), (cc.l, cc.j)
    xs = (cc.n, cc.c, cc.h, cc.w)
    ws = (cc.k, cc.c // cc.g, cc.r, cc.s)

    if direction == "fwd":
        if algo == "depthwise":
            # depthwise (g == c): the lowered computation is the grouped
            # direct kernel with one group per channel — the dedicated
            # solver differs only in host-side loop structure, and its
            # channel-block tile rides the shared block_k key
            return lambda x, w: (direct.conv2d_direct(
                x, w, stride=stride, pad=pad, dilation=dil, groups=cc.g,
                block_k=bk),)
        if algo == "gemm":
            return lambda x, w: (im2col_gemm.conv2d_im2col(
                x, w, stride=stride, pad=pad, dilation=dil),)
        if algo == "direct":
            return lambda x, w: (direct.conv2d_direct(
                x, w, stride=stride, pad=pad, dilation=dil, groups=cc.g,
                block_k=bk),)
        if algo == "implicit":
            return lambda x, w: (implicit_gemm.conv2d_implicit_gemm(
                x, w, stride=stride, pad=pad, dilation=dil, block_k=bk),)
        if algo == "winograd":
            return lambda x, w: (winograd.conv2d_winograd(x, w, pad=pad),)
        if algo == "fft":
            return lambda x, w: (fft_conv.conv2d_fft(
                x, w, stride=stride, pad=pad),)
    if direction == "bwd":
        if algo == "gemm":
            return lambda dy, w: (im2col_gemm.conv2d_im2col_bwd_data(
                dy, w, xs, stride=stride, pad=pad, dilation=dil),)
        if algo == "direct":
            return lambda dy, w: (direct.conv2d_direct_bwd_data(
                dy, w, xs, stride=stride, pad=pad, dilation=dil,
                block_k=bk),)
        if algo == "winograd":
            return lambda dy, w: (winograd.conv2d_winograd_bwd_data(
                dy, w, xs, pad=pad),)
    if direction == "wrw":
        if algo == "gemm":
            return lambda dy, x: (im2col_gemm.conv2d_im2col_bwd_weights(
                dy, x, ws, stride=stride, pad=pad, dilation=dil),)
        if algo == "direct":
            return lambda dy, x: (direct.conv2d_direct_bwd_weights(
                dy, x, ws, stride=stride, pad=pad, dilation=dil,
                block_k=bk),)
    raise ValueError(f"{direction}/{algo}")


def conv_in_specs(direction, cc, dtype, layout="nchw"):
    ho, wo = cc.out_hw()
    if layout == "nhwc":
        # channels-last physical shapes; sig params stay logical NCHW
        xs = (cc.n, cc.h, cc.w, cc.c)
        ws = (cc.k, cc.r, cc.s, cc.c // cc.g)
        ys = (cc.n, ho, wo, cc.k)
    else:
        xs = (cc.n, cc.c, cc.h, cc.w)
        ws = (cc.k, cc.c // cc.g, cc.r, cc.s)
        ys = (cc.n, cc.k, ho, wo)
    if direction == "fwd":
        return [spec(xs, dtype), spec(ws, dtype)]
    if direction == "bwd":
        return [spec(ys, dtype), spec(ws, dtype)]
    if direction == "wrw":
        return [spec(ys, dtype), spec(xs, dtype)]
    raise ValueError(direction)


def nhwc_transpose_scratch(cc):
    """f32 NCHW staging copies (x + w + y) charged by the
    transpose-at-boundary fallback paths — mirrors
    solvers::nhwc_transpose_scratch on the Rust side."""
    ho, wo = cc.out_hw()
    return 4 * (cc.n * cc.c * cc.h * cc.w
                + cc.k * (cc.c // cc.g) * cc.r * cc.s
                + cc.n * cc.k * ho * wo)


def conv_workspace(direction, algo, cc, dtype="f32", layout="nchw"):
    """One workspace formula per algorithm, shared with the Rust solvers
    (solvers::workspace_for — the reference executor's honest footprint).
    All scratch is **f32 accumulate-domain** regardless of the storage
    dtype: bf16/f16 operands decode into the gemm packing panels and the
    winograd transform buffers at pack/load time, they are never stored
    reduced (docs/NUMERICS.md); fft spectra are always complex-f32."""
    del dtype  # storage dtype does not size the accumulate-domain scratch
    ho, wo = cc.out_hw()
    nhwc = layout == "nhwc"
    if algo == "gemm":
        if nhwc:
            # NHWC computes y(HoWo, K) = col(HoWo, CRS) · w(K, CRS)ᵀ —
            # the channels-last column matrix packs as A and the weights
            # as B, so the MR/NR strip padding swaps roles vs NCHW
            crs = cc.c * cc.r * cc.s
            howo = ho * wo
            pa = -(-howo // im2col_gemm.GEMM_MR) * im2col_gemm.GEMM_MR * crs
            pb = -(-cc.k // im2col_gemm.GEMM_NR) * im2col_gemm.GEMM_NR * crs
            return 4 * (crs * howo + pa + pb)
        return im2col_gemm.workspace_bytes(
            (cc.n, cc.c, cc.h, cc.w), (cc.k, cc.c, cc.r, cc.s),
            (cc.n, cc.k, ho, wo), itemsize=4)
    if algo == "fft":
        # the FFT planes are inherently channel-planar, so NHWC always
        # pays the boundary transposes on top of the spectra
        return fft_conv.workspace_bytes(
            (cc.n, cc.c, cc.h, cc.w), (cc.k, cc.c, cc.r, cc.s),
            pad=(cc.p, cc.q)) + (nhwc_transpose_scratch(cc) if nhwc else 0)
    if algo == "winograd":
        # bwd-data tiles the (H, W) dx extent (adjoint pipeline)
        extent = (cc.h, cc.w) if direction == "bwd" else (ho, wo)
        return winograd.workspace_bytes(
            (cc.n, cc.c, cc.h, cc.w), (cc.k, cc.c // cc.g, cc.r, cc.s),
            extent, itemsize=4) + (nhwc_transpose_scratch(cc) if nhwc else 0)
    if algo == "direct" and nhwc and direction != "fwd":
        # fwd runs natively over channels-last strides (workspace-free);
        # bwd/wrw transpose at the boundary and account for it honestly
        return nhwc_transpose_scratch(cc)
    return 0


def emit_conv_family(em):
    dir_tags = {"fwd": ("a", "b"), "bwd": ("c", "d"), "wrw": ("e", "f")}
    for cset, one_by_one in ((configs.FIG6_1X1, True),
                             (configs.FIG6_NON1X1, False)):
        for cc in cset:
            for direction in ("fwd", "bwd", "wrw"):
                panel = dir_tags[direction][0 if one_by_one else 1]
                algos = fwd_algos(cc) if direction == "fwd" else (
                    bwd_algos(cc) if direction == "bwd" else ["gemm", "direct"])
                for algo in algos:
                    em.emit(
                        conv_sig(direction, algo, cc, "f32"),
                        make_conv_fn(direction, algo, cc),
                        conv_in_specs(direction, cc, "f32"),
                        primitive="conv", algo=algo, direction=direction,
                        dtype="f32", tags=(f"fig6{panel}",),
                        params=cc.as_dict(),
                        workspace_bytes=conv_workspace(direction, algo, cc),
                    )
    # Mixed-precision set (mirrors configs::builtin_artifacts): bf16 is
    # a first-class execution dtype — every applicable fwd algorithm on
    # the exemplar configs, bwd/wrw for the gemm/direct universal pair,
    # and an f16 slice of the same fwd surface.
    for cc in configs.MP_FWD_CONFIGS:
        for algo in fwd_algos(cc):
            em.emit(
                conv_sig("fwd", algo, cc, "bf16"),
                make_conv_fn("fwd", algo, cc),
                conv_in_specs("fwd", cc, "bf16"),
                primitive="conv", algo=algo, direction="fwd", dtype="bf16",
                tags=("bf16",), params=cc.as_dict(),
                workspace_bytes=conv_workspace("fwd", algo, cc, dtype="bf16"),
            )
    mp_bwd = configs.MP_BWD_CONFIG
    for algo in bwd_algos(mp_bwd):
        em.emit(
            conv_sig("bwd", algo, mp_bwd, "bf16"),
            make_conv_fn("bwd", algo, mp_bwd),
            conv_in_specs("bwd", mp_bwd, "bf16"),
            primitive="conv", algo=algo, direction="bwd", dtype="bf16",
            tags=("bf16",), params=mp_bwd.as_dict(),
            workspace_bytes=conv_workspace("bwd", algo, mp_bwd,
                                           dtype="bf16"),
        )
    for algo in ("gemm", "direct"):
        em.emit(
            conv_sig("wrw", algo, mp_bwd, "bf16"),
            make_conv_fn("wrw", algo, mp_bwd),
            conv_in_specs("wrw", mp_bwd, "bf16"),
            primitive="conv", algo=algo, direction="wrw", dtype="bf16",
            tags=("bf16",), params=mp_bwd.as_dict(),
            workspace_bytes=conv_workspace("wrw", algo, mp_bwd,
                                           dtype="bf16"),
        )
    for cc in (configs.FIG6_1X1[0], configs.FIG6_NON1X1[0]):
        for algo in fwd_algos(cc):
            em.emit(
                conv_sig("fwd", algo, cc, "f16"),
                make_conv_fn("fwd", algo, cc),
                conv_in_specs("fwd", cc, "f16"),
                primitive="conv", algo=algo, direction="fwd", dtype="f16",
                tags=("f16",), params=cc.as_dict(),
                workspace_bytes=conv_workspace("fwd", algo, cc,
                                               dtype="f16"),
            )
    # grouped convolutions keep the direct fallback; depthwise-shaped
    # entries (g == c) also get the dedicated depthwise solver's
    # artifact in both layouts (mirrors configs.rs)
    for cc in configs.GROUPED_CONFIGS:
        em.emit(
            conv_sig("fwd", "direct", cc, "f32"),
            make_conv_fn("fwd", "direct", cc),
            conv_in_specs("fwd", cc, "f32"),
            primitive="conv", algo="direct", direction="fwd", dtype="f32",
            tags=("grouped",), params=cc.as_dict(),
        )
        if cc.g == cc.c and cc.g > 1:
            for layout, tag in (("nchw", "depthwise"),
                                ("nhwc", "depthwise-nhwc")):
                fn = make_conv_fn("fwd", "depthwise", cc)
                em.emit(
                    conv_sig("fwd", "depthwise", cc, "f32", layout=layout),
                    nhwc_wrap(fn) if layout == "nhwc" else fn,
                    conv_in_specs("fwd", cc, "f32", layout=layout),
                    primitive="conv", algo="depthwise", direction="fwd",
                    dtype="f32", tags=(tag,), params=cc.as_dict(),
                )
    # depthwise tuned variants: the solver's channel-block grid on the
    # first depthwise exemplar, per layout (`-bk` reuses the direct
    # solver's block_k key — the tuning grammar stays closed)
    dw = configs.GROUPED_CONFIGS[0]
    assert dw.g == dw.c and dw.g > 1
    for bk in configs.DEPTHWISE_BLOCK_GRID:
        if bk > max(dw.c, 4):
            continue
        for layout in ("nchw", "nhwc"):
            fn = make_conv_fn("fwd", "depthwise", dw, bk=bk)
            em.emit(
                conv_sig("fwd", "depthwise", dw, "f32", bk=bk,
                         layout=layout),
                nhwc_wrap(fn) if layout == "nhwc" else fn,
                conv_in_specs("fwd", dw, "f32", layout=layout),
                primitive="conv", algo="depthwise", direction="fwd",
                dtype="f32", tags=("tune-depthwise",), params=dw.as_dict(),
                tuning={"block_k": bk},
            )
    # NHWC exemplar set (mirrors configs.rs): the full applicable fwd
    # zoo on one config per filter family, bwd/wrw via the
    # transpose-at-boundary direct path, a bf16 slice, and tuned
    # `-bk`/`-gt` variants so per-layout tuning sessions resolve NHWC
    # artifacts. Sig params stay logical NCHW; specs are channels-last.
    for cc in configs.NHWC_CONFIGS:
        for algo in fwd_algos(cc):
            em.emit(
                conv_sig("fwd", algo, cc, "f32", layout="nhwc"),
                nhwc_wrap(make_conv_fn("fwd", algo, cc)),
                conv_in_specs("fwd", cc, "f32", layout="nhwc"),
                primitive="conv", algo=algo, direction="fwd", dtype="f32",
                tags=("nhwc",), params=cc.as_dict(),
                workspace_bytes=conv_workspace("fwd", algo, cc,
                                               layout="nhwc"),
            )
    nh = configs.FIG6_NON1X1[0]
    for direction in ("bwd", "wrw"):
        em.emit(
            conv_sig(direction, "direct", nh, "f32", layout="nhwc"),
            nhwc_wrap(make_conv_fn(direction, "direct", nh)),
            conv_in_specs(direction, nh, "f32", layout="nhwc"),
            primitive="conv", algo="direct", direction=direction,
            dtype="f32", tags=("nhwc",), params=nh.as_dict(),
            workspace_bytes=conv_workspace(direction, "direct", nh,
                                           layout="nhwc"),
        )
    for algo in ("direct", "gemm"):
        em.emit(
            conv_sig("fwd", algo, nh, "bf16", layout="nhwc"),
            nhwc_wrap(make_conv_fn("fwd", algo, nh)),
            conv_in_specs("fwd", nh, "bf16", layout="nhwc"),
            primitive="conv", algo=algo, direction="fwd", dtype="bf16",
            tags=("nhwc-bf16",), params=nh.as_dict(),
            workspace_bytes=conv_workspace("fwd", algo, nh, dtype="bf16",
                                           layout="nhwc"),
        )
    tc = configs.TUNE_CONFIGS[0]
    for bk in configs.DIRECT_BLOCK_K:
        em.emit(
            conv_sig("fwd", "direct", tc, "f32", bk=bk, layout="nhwc"),
            nhwc_wrap(make_conv_fn("fwd", "direct", tc, bk=bk)),
            conv_in_specs("fwd", tc, "f32", layout="nhwc"),
            primitive="conv", algo="direct", direction="fwd", dtype="f32",
            tags=("tune-nhwc",), params=tc.as_dict(),
            tuning={"block_k": bk},
        )
    for gt in configs.GEMM_TILE_GRID:
        em.emit(
            conv_sig("fwd", "gemm", tc, "f32", gt=gt, layout="nhwc"),
            nhwc_wrap(make_conv_fn("fwd", "gemm", tc)),
            conv_in_specs("fwd", tc, "f32", layout="nhwc"),
            primitive="conv", algo="gemm", direction="fwd", dtype="f32",
            tags=("tune-nhwc",), params=tc.as_dict(),
            workspace_bytes=conv_workspace("fwd", "gemm", tc,
                                           layout="nhwc"),
            tuning={"gt": gt},
        )
    # int8 inference: i8 inputs, exact f32 accumulation/output
    for cc in configs.INT8_CONFIGS:
        em.emit(
            f"conv_fwd-direct-{cc.sig_params()}-i8",
            lambda x, w, _cc=cc: (direct.conv2d_direct(
                x, w, stride=(_cc.u, _cc.v), pad=(_cc.p, _cc.q),
                out_dtype=jnp.float32),),
            [spec((cc.n, cc.c, cc.h, cc.w), "i8"),
             spec((cc.k, cc.c, cc.r, cc.s), "i8")],
            primitive="conv", algo="direct", direction="fwd", dtype="i8",
            tags=("int8",), params=cc.as_dict(),
        )
    # tuning variants: direct block_k tiles + winograd transform-domain
    # parallelism (where the winograd solver applies) + the blocked-GEMM
    # tile grid — emitted per dtype (configs.TUNE_DTYPES), because tuned
    # variants resolve through per-dtype perf-db keys on the Rust side
    for cc in configs.TUNE_CONFIGS:
        for dt in configs.TUNE_DTYPES:
            dtag = "tune" if dt == "f32" else "tune-" + dt
            for bk in configs.DIRECT_BLOCK_K:
                em.emit(
                    conv_sig("fwd", "direct", cc, dt, bk=bk),
                    make_conv_fn("fwd", "direct", cc, bk=bk),
                    conv_in_specs("fwd", cc, dt),
                    primitive="conv", algo="direct", direction="fwd",
                    dtype=dt, tags=(dtag,), params=cc.as_dict(),
                    tuning={"block_k": bk},
                )
            if "winograd" in fwd_algos(cc):
                for wt in configs.WINOGRAD_TILE_THREADS:
                    # wt only changes host-side parallelism; the lowered
                    # computation is the same winograd pipeline
                    em.emit(
                        conv_sig("fwd", "winograd", cc, dt, wt=wt),
                        make_conv_fn("fwd", "winograd", cc),
                        conv_in_specs("fwd", cc, dt),
                        primitive="conv", algo="winograd", direction="fwd",
                        dtype=dt,
                        tags=("tune-wino" if dt == "f32" else dtag,),
                        params=cc.as_dict(),
                        workspace_bytes=conv_workspace("fwd", "winograd",
                                                       cc),
                        tuning={"wt": wt},
                    )
            for gt in configs.GEMM_TILE_GRID:
                # gt only changes the host-side MC x NC cache blocking;
                # the lowered computation is the same im2col+GEMM pipeline
                em.emit(
                    conv_sig("fwd", "gemm", cc, dt, gt=gt),
                    make_conv_fn("fwd", "gemm", cc),
                    conv_in_specs("fwd", cc, dt),
                    primitive="conv", algo="gemm", direction="fwd",
                    dtype=dt,
                    tags=("tune-gemm" if dt == "f32" else dtag,),
                    params=cc.as_dict(),
                    workspace_bytes=conv_workspace("fwd", "gemm", cc),
                    tuning={"gt": gt},
                )


# ---------------------------------------------------------------------------
# Fusion artifacts (Figure 7 + fusion-plan execution)
# ---------------------------------------------------------------------------


def _cba_wino_row_ok(f, stride, c):
    """Table I winograd-row channel constraints (fusion::mdgraph's
    cba_wino_s1 / cba_wino_s2, transcribed row for row)."""
    if stride == 1:
        if f in (1, 2):
            return c >= 18
        if f == 3:
            return c >= 18 and c % 2 == 0
        if 4 <= f <= 6:
            return 4 * c >= 18
        if 7 <= f <= 9:
            return 12 * c >= 18
        if 10 <= f <= 12:
            return 16 * c >= 18
        return f > 12
    if stride == 2:
        if f == 1:
            return 2 * c >= 18
        if 2 <= f <= 6:
            return 4 * c >= 18
        if f == 7:
            return 12 * c >= 18
        if 8 <= f <= 12:
            return 16 * c >= 18
        return f > 12
    return False


def cba_conv_algo(cc):
    """Conv algorithm a relu/f32 CBA plan over this config selects —
    the same decision procedure as fusion::mdgraph (and the Rust
    emitter's configs::cba_conv_algo, which calls the graph directly):
    the direct-1x1 accept is checked first, then the Table I winograd
    rows for strides 1 and 2; anything the graph rejects executes
    direct. The executing backends guard separately for the one
    winograd variant they implement (F(2,3): 3x3/stride-1)."""
    # the graph keys on (filter, stride, pad, channels) only — exactly
    # the attributes PlanAttrs carries; dilation/groups are invisible to
    # it and the executing backend guards for its own kernel's limits
    square = cc.r == cc.s
    uniform = cc.u == cc.v
    # accept order matters: CBA-direct-1x1 wins before the winograd rows
    if square and cc.r == 1 and (cc.u, cc.v) == (1, 1) \
            and (cc.p, cc.q) == (0, 0):
        return "direct"
    if square and uniform and _cba_wino_row_ok(cc.r, cc.u, cc.c):
        return "winograd"
    return "direct"


def emit_fusion_family(em):
    # Figure 7a: CBA fused vs {conv, bias, act} separate
    for cc in configs.FIG7A:
        stride, pad = (cc.u, cc.v), (cc.p, cc.q)
        xs = (cc.n, cc.c, cc.h, cc.w)
        ws = (cc.k, cc.c, cc.r, cc.s)
        ho, wo = cc.out_hw()
        ys = (cc.n, cc.k, ho, wo)
        base = cc.sig_params()
        # the lowered kernel must match the recorded conv_algo label —
        # winograd rows get the F(2,3) lowering where it applies (the
        # same guard the interp backend's wino_executable applies),
        # everything else the direct fused kernel
        algo_name = cba_conv_algo(cc)
        if algo_name == "winograd" and (cc.r, cc.s) == (3, 3) \
                and (cc.u, cc.v) == (1, 1):
            fn = lambda x, w, b, _p=pad: (
                fused.conv_bias_act_winograd(x, w, b, pad=_p, mode="relu"),)
        else:
            algo_name = "direct"
            fn = lambda x, w, b, _s=stride, _p=pad: (
                fused.conv_bias_act(x, w, b, stride=_s, pad=_p,
                                    mode="relu"),)
        em.emit(f"cba-relu-{base}-f32", fn,
                [spec(xs), spec(ws), spec((cc.k,))],
                primitive="fusion", algo="cba", direction="fwd",
                tags=("fig7a",),
                params={**cc.as_dict(), "conv_algo": algo_name})
        em.emit(f"conv_fwd-direct-{base}-f32",
                make_conv_fn("fwd", "direct", cc),
                conv_in_specs("fwd", cc, "f32"),
                primitive="conv", algo="direct", direction="fwd",
                tags=("fig7a-sep",), params=cc.as_dict())
        em.emit(f"bias-{cc.n}x{cc.k}x{ho}x{wo}-f32",
                lambda y, b: (tensor_ops.op_tensor_bias(y, b),),
                [spec(ys), spec((cc.k,))],
                primitive="tensor_op", algo="bias", direction="fwd",
                tags=("fig7a-sep",), params=cc.as_dict())
        em.emit(f"act-relu-{cc.n}x{cc.k}x{ho}x{wo}-f32",
                lambda y: (activations.activation_fwd(y, "relu"),),
                [spec(ys)],
                primitive="activation", algo="relu", direction="fwd",
                tags=("fig7a-sep",), params=cc.as_dict())

    # Figure 7b: BN+A fused vs {bn_infer, act} separate
    n = 4
    for (c, h, w) in configs.FIG7B:
        shape = (n, c, h, w)
        label = f"{c}x{h}x{w}"
        pv = {"n": n, "c": c, "h": h, "w": w, "label": label}
        em.emit(f"bna-relu-n{n}c{c}h{h}w{w}-f32",
                lambda x, g, b, m, v: (
                    fused.bn_act(x, g, b, m, v, mode="relu"),),
                [spec(shape), spec((c,)), spec((c,)), spec((c,)),
                 spec((c,))],
                primitive="fusion", algo="bna", direction="fwd",
                tags=("fig7b",), params=pv)
        em.emit(f"bn_infer-spatial-n{n}c{c}h{h}w{w}-f32",
                lambda x, g, b, m, v: (
                    batchnorm.spatial_fwd_infer(x, g, b, m, v),),
                [spec(shape), spec((c,)), spec((c,)), spec((c,)),
                 spec((c,))],
                primitive="batchnorm", algo="spatial_infer",
                direction="fwd", tags=("fig7b-sep",), params=pv)
        em.emit(f"act-relu-{n}x{c}x{h}x{w}-f32",
                lambda x: (activations.activation_fwd(x, "relu"),),
                [spec(shape)],
                primitive="activation", algo="relu", direction="fwd",
                tags=("fig7b-sep",), params=pv)

    # CBNA (Tables I/II row 1) — one exemplar per stride for plan execution
    for cc in (configs.ConvConfig(2, 8, 14, 14, 8, 3, 3, p=1, q=1),
               configs.ConvConfig(2, 8, 14, 14, 8, 3, 3, u=2, v=2, p=1, q=1)):
        xs = (cc.n, cc.c, cc.h, cc.w)
        ws = (cc.k, cc.c, cc.r, cc.s)
        em.emit(f"cbna-relu-{cc.sig_params()}-f32",
                lambda x, w, b, g, bb, m, v, _cc=cc: (
                    fused.conv_bias_bn_act(
                        x, w, b, g, bb, m, v, stride=(_cc.u, _cc.v),
                        pad=(_cc.p, _cc.q), mode="relu"),),
                [spec(xs), spec(ws), spec((cc.k,)), spec((cc.k,)),
                 spec((cc.k,)), spec((cc.k,)), spec((cc.k,))],
                primitive="fusion", algo="cbna", direction="fwd",
                tags=("fusion-exec",),
                params={**cc.as_dict(), "conv_algo": "direct"})

    # Table II executable half-precision exemplars (mirrors the Rust
    # emitter): bf16 fuses only through the direct kernel — CBA via the
    # 1x1 row, CBNA via row 1. No winograd bf16 plan exists, because the
    # metadata graph rejects it outright.
    cc = configs.ConvConfig(4, 16, 28, 28, 32, 1, 1)
    xs = (cc.n, cc.c, cc.h, cc.w)
    ws = (cc.k, cc.c, cc.r, cc.s)
    em.emit(f"cba-relu-{cc.sig_params()}-bf16",
            lambda x, w, b: (fused.conv_bias_act(
                x, w, b, stride=(1, 1), pad=(0, 0), mode="relu"),),
            [spec(xs, "bf16"), spec(ws, "bf16"), spec((cc.k,), "bf16")],
            primitive="fusion", algo="cba", direction="fwd", dtype="bf16",
            tags=("fusion-bf16",),
            params={**cc.as_dict(), "conv_algo": "direct"})
    cc = configs.ConvConfig(2, 8, 14, 14, 8, 3, 3, p=1, q=1)
    xs = (cc.n, cc.c, cc.h, cc.w)
    ws = (cc.k, cc.c, cc.r, cc.s)
    em.emit(f"cbna-relu-{cc.sig_params()}-bf16",
            lambda x, w, b, g, bb, m, v, _cc=cc: (
                fused.conv_bias_bn_act(
                    x, w, b, g, bb, m, v, stride=(_cc.u, _cc.v),
                    pad=(_cc.p, _cc.q), mode="relu"),),
            [spec(xs, "bf16"), spec(ws, "bf16")]
            + [spec((cc.k,), "bf16")] * 5,
            primitive="fusion", algo="cbna", direction="fwd", dtype="bf16",
            tags=("fusion-bf16",),
            params={**cc.as_dict(), "conv_algo": "direct"})

    # NHWC CBA exemplar (mirrors configs.rs): the direct 1x1 row is the
    # one CBA family the layout axis admits — winograd rows are
    # NCHW-only in the mdgraph. Channels-last specs, `-nhwc` sig tail.
    cc = configs.ConvConfig(4, 16, 28, 28, 32, 1, 1)
    assert cba_conv_algo(cc) == "direct"
    em.emit(f"cba-relu-{cc.sig_params()}-f32-nhwc",
            lambda x, w, b: tuple(
                jnp.transpose(o, (0, 2, 3, 1)) for o in (
                    fused.conv_bias_act(
                        jnp.transpose(x, (0, 3, 1, 2)),
                        jnp.transpose(w, (0, 3, 1, 2)), b,
                        stride=(1, 1), pad=(0, 0), mode="relu"),)),
            [spec((cc.n, cc.h, cc.w, cc.c)),
             spec((cc.k, cc.r, cc.s, cc.c)), spec((cc.k,))],
            primitive="fusion", algo="cba", direction="fwd",
            tags=("fusion-nhwc",),
            params={**cc.as_dict(), "conv_algo": "direct"})

    # Winograd CBA exemplar (Table I winograd rows): 3x3/s1, c >= 18 and
    # even, relu — the plan selects winograd and the backends execute the
    # F(2,3) pipeline. Separate-op artifacts ride along for the
    # fused-vs-separate parity suite.
    cc = configs.ConvConfig(4, 32, 14, 14, 8, 3, 3, p=1, q=1)
    assert cba_conv_algo(cc) == "winograd"
    xs = (cc.n, cc.c, cc.h, cc.w)
    ws = (cc.k, cc.c, cc.r, cc.s)
    ho, wo = cc.out_hw()
    ys = (cc.n, cc.k, ho, wo)
    em.emit(f"cba-relu-{cc.sig_params()}-f32",
            lambda x, w, b: (
                fused.conv_bias_act_winograd(x, w, b, pad=(1, 1),
                                             mode="relu"),),
            [spec(xs), spec(ws), spec((cc.k,))],
            primitive="fusion", algo="cba", direction="fwd",
            tags=("fusion-wino",),
            params={**cc.as_dict(), "conv_algo": "winograd"})
    for a in ("direct", "winograd"):
        em.emit(conv_sig("fwd", a, cc, "f32"),
                make_conv_fn("fwd", a, cc),
                conv_in_specs("fwd", cc, "f32"),
                primitive="conv", algo=a, direction="fwd",
                tags=("fusion-wino-sep",), params=cc.as_dict(),
                workspace_bytes=conv_workspace("fwd", a, cc))
    em.emit(f"bias-{cc.n}x{cc.k}x{ho}x{wo}-f32",
            lambda y, b: (tensor_ops.op_tensor_bias(y, b),),
            [spec(ys), spec((cc.k,))],
            primitive="tensor_op", algo="bias", direction="fwd",
            tags=("fusion-wino-sep",), params=cc.as_dict())
    em.emit(f"act-relu-{cc.n}x{cc.k}x{ho}x{wo}-f32",
            lambda y: (activations.activation_fwd(y, "relu"),),
            [spec(ys)],
            primitive="activation", algo="relu", direction="fwd",
            tags=("fusion-wino-sep",), params=cc.as_dict())


# ---------------------------------------------------------------------------
# Other primitives
# ---------------------------------------------------------------------------


def emit_primitives(em):
    for shape in configs.BN_SHAPES:
        n, c, h, w = shape
        base = f"n{n}c{c}h{h}w{w}"
        pv = {"n": n, "c": c, "h": h, "w": w}
        em.emit(f"bn_train-spatial-{base}-f32",
                lambda x, g, b: batchnorm.spatial_fwd_train(x, g, b),
                [spec(shape), spec((c,)), spec((c,))],
                primitive="batchnorm", algo="spatial_train",
                direction="fwd", tags=("prim",), params=pv)
        em.emit(f"bn_bwd-spatial-{base}-f32",
                lambda x, dy, g, m, v: batchnorm.spatial_bwd(x, dy, g, m, v),
                [spec(shape), spec(shape), spec((c,)), spec((c,)),
                 spec((c,))],
                primitive="batchnorm", algo="spatial_bwd", direction="bwd",
                tags=("prim",), params=pv)
        em.emit(f"bn_train-peract-{base}-f32",
                lambda x, g, b: batchnorm.peract_fwd_train(x, g, b),
                [spec(shape), spec((c, h, w)), spec((c, h, w))],
                primitive="batchnorm", algo="peract_train", direction="fwd",
                tags=("prim",), params=pv)
        em.emit(f"bn_bwd-peract-{base}-f32",
                lambda x, dy, g, m, v: batchnorm.peract_bwd(x, dy, g, m, v),
                [spec(shape), spec(shape)] + [spec((c, h, w))] * 3,
                primitive="batchnorm", algo="peract_bwd", direction="bwd",
                tags=("prim",), params=pv)
        em.emit(f"bn_infer-peract-{base}-f32",
                lambda x, g, b, m, v: (
                    batchnorm.peract_fwd_infer(x, g, b, m, v),),
                [spec(shape)] + [spec((c, h, w))] * 4,
                primitive="batchnorm", algo="peract_infer", direction="fwd",
                tags=("prim",), params=pv)

    for shape, win, stride, pad, mode in configs.POOL_SHAPES:
        n, c, h, w = shape
        ho = (h + 2 * pad[0] - win[0]) // stride[0] + 1
        wo = (w + 2 * pad[1] - win[1]) // stride[1] + 1
        base = f"{mode}-n{n}c{c}h{h}w{w}k{win[0]}x{win[1]}u{stride[0]}p{pad[0]}"
        pv = {"n": n, "c": c, "h": h, "w": w, "win": list(win),
              "stride": list(stride), "pad": list(pad), "mode": mode}
        em.emit(f"pool_fwd-{base}-f32",
                lambda x, _w=win, _s=stride, _p=pad, _m=mode: (
                    pooling.pool2d_fwd(x, window=_w, stride=_s, pad=_p,
                                       mode=_m),),
                [spec(shape)],
                primitive="pooling", algo=mode, direction="fwd",
                tags=("prim",), params=pv)
        em.emit(f"pool_bwd-{base}-f32",
                lambda x, y, dy, _w=win, _s=stride, _p=pad, _m=mode: (
                    pooling.pool2d_bwd(x, y, dy, window=_w, stride=_s,
                                       pad=_p, mode=_m),),
                [spec(shape), spec((n, c, ho, wo)), spec((n, c, ho, wo))],
                primitive="pooling", algo=mode, direction="bwd",
                tags=("prim",), params=pv)

    for shape in configs.SOFTMAX_SHAPES:
        n, c, h, w = shape
        base = f"n{n}c{c}h{h}w{w}"
        for log in (False, True):
            nm = "log_softmax" if log else "softmax"
            em.emit(f"{nm}_fwd-{base}-f32",
                    lambda x, _l=log: (softmax.softmax_fwd(x, log=_l),),
                    [spec(shape)],
                    primitive="softmax", algo=nm, direction="fwd",
                    tags=("prim",), params={"n": n, "c": c, "h": h, "w": w})
            em.emit(f"{nm}_bwd-{base}-f32",
                    lambda y, dy, _l=log: (softmax.softmax_bwd(y, dy, log=_l),),
                    [spec(shape), spec(shape)],
                    primitive="softmax", algo=nm, direction="bwd",
                    tags=("prim",), params={"n": n, "c": c, "h": h, "w": w})

    for shape in configs.ACT_SHAPES:
        n, c, h, w = shape
        for mode in configs.ACT_MODES:
            alpha = {"leaky_relu": 0.01}.get(mode, 0.0)
            em.emit(f"act_fwd-{mode}-n{n}c{c}h{h}w{w}-f32",
                    lambda x, _m=mode, _a=alpha: (
                        activations.activation_fwd(x, _m, _a),),
                    [spec(shape)],
                    primitive="activation", algo=mode, direction="fwd",
                    tags=("prim",), params={"n": n, "c": c, "h": h, "w": w})
            em.emit(f"act_bwd-{mode}-n{n}c{c}h{h}w{w}-f32",
                    lambda x, dy, _m=mode, _a=alpha: (
                        activations.activation_bwd(x, dy, _m, _a),),
                    [spec(shape), spec(shape)],
                    primitive="activation", algo=mode, direction="bwd",
                    tags=("prim",), params={"n": n, "c": c, "h": h, "w": w})

    for shape in configs.LRN_SHAPES:
        n, c, h, w = shape
        em.emit(f"lrn_fwd-n{n}c{c}h{h}w{w}-f32",
                lambda x: (lrn.lrn_fwd(x),),
                [spec(shape)],
                primitive="lrn", algo="cross_channel", direction="fwd",
                tags=("prim",), params={"n": n, "c": c, "h": h, "w": w})

    shape = (4, 16, 14, 14)
    n, c, h, w = shape
    for op in ("add", "mul"):
        em.emit(f"op_tensor-{op}-n{n}c{c}h{h}w{w}-f32",
                lambda a, b, _o=op: (tensor_ops.op_tensor(a, b, op=_o),),
                [spec(shape), spec(shape)],
                primitive="tensor_op", algo=op, direction="fwd",
                tags=("prim",), params={"n": n, "c": c, "h": h, "w": w})

    # CTC loss
    b_, t_, v_, l_ = 4, 8, 6, 3
    em.emit(f"ctc_loss-b{b_}t{t_}v{v_}l{l_}-f32",
            lambda lp, lab, il, ll: (ctc.ctc_loss(lp, lab, il, ll),),
            [spec((b_, t_, v_)), spec((b_, l_), "i32"), spec((b_,), "i32"),
             spec((b_,), "i32")],
            primitive="ctc", algo="forward", direction="fwd",
            tags=("prim",), params={"b": b_, "t": t_, "v": v_, "l": l_})


# ---------------------------------------------------------------------------
# RNN artifacts
# ---------------------------------------------------------------------------


def emit_rnn_family(em):
    def emit_one(rc, variant, tags):
        t, b, x, h = rc.t, rc.b, rc.x, rc.hid
        if rc.cell == "lstm":
            gates = 4
            fn = (rnn_cells.lstm_seq_fused if variant == "fused"
                  else rnn_cells.lstm_seq_naive)
            f = lambda xs, h0, c0, W, R: (fn(xs, h0, c0, W, R),)
            ins = [spec((t, b, x)), spec((b, h)), spec((b, h)),
                   spec((gates * h, x)), spec((gates * h, h))]
        elif rc.cell == "gru":
            gates = 3
            f = lambda xs, h0, W, R: (rnn_cells.gru_seq_fused(xs, h0, W, R),)
            ins = [spec((t, b, x)), spec((b, h)),
                   spec((gates * h, x)), spec((gates * h, h))]
        else:
            f = lambda xs, h0, W, R, _a=rc.act: (
                rnn_cells.vanilla_seq_fused(xs, h0, W, R, act=_a),)
            ins = [spec((t, b, x)), spec((b, h)), spec((h, x)),
                   spec((h, h))]
        em.emit(f"rnn-{rc.cell}-{variant}-{rc.sig_params()}-f32",
                f, ins, primitive="rnn", algo=f"{rc.cell}_{variant}",
                direction="fwd", tags=tags, params=rc.as_dict())

    for rc in configs.RNN_CONFIGS:
        emit_one(rc, "fused", ("rnn",))

    # ablation sweep: fused vs naive LSTM over T
    base = configs.RNN_ABLATION_BASE
    for t in configs.RNN_ABLATION_T:
        rc = configs.RnnConfig("lstm", t, base.b, base.x, base.hid)
        emit_one(rc, "fused", ("abl-rnn",))
        emit_one(rc, "naive", ("abl-rnn",))

    # bidirectional exemplar
    rc = configs.RNN_CONFIGS[0]
    t, b, x, h = rc.t, rc.b, rc.x, rc.hid
    em.emit(f"rnn-lstm-bidir-{rc.sig_params()}-f32",
            lambda xs, h0, c0, W, R: (
                rnn_cells.bidirectional(rnn_cells.lstm_seq_fused, xs, h0,
                                        c0, W, R),),
            [spec((t, b, x)), spec((b, h)), spec((b, h)),
             spec((4 * h, x)), spec((4 * h, h))],
            primitive="rnn", algo="lstm_bidir", direction="fwd",
            tags=("rnn",), params=rc.as_dict())


# ---------------------------------------------------------------------------
# E2E CNN artifacts
# ---------------------------------------------------------------------------


def emit_cnn(em):
    cfg = configs.CNN
    p = model.cnn_init(cfg)
    pspecs = [spec(p[k].shape) for k in model.PARAM_ORDER]
    b, c, s = cfg["batch"], cfg["channels"], cfg["image"]
    xspec = spec((b, c, s, s))
    lspec = spec((b,), "i32")

    def train_fn(*args):
        params = dict(zip(model.PARAM_ORDER, args[:7]))
        x, labels = args[7], args[8]
        return model.cnn_train_step(params, x, labels, cfg["lr"])

    em.emit("cnn_train-f32", train_fn, pspecs + [xspec, lspec],
            primitive="model", algo="cnn_train", direction="fwd",
            tags=("e2e",), params=cfg)

    def infer_fn(*args):
        params = dict(zip(model.PARAM_ORDER, args[:7]))
        return model.cnn_infer(params, args[7])

    em.emit("cnn_infer-f32", infer_fn, pspecs + [xspec],
            primitive="model", algo="cnn_infer", direction="fwd",
            tags=("e2e",), params=cfg)

    em.emit("cnn_datagen-f32", model.cnn_datagen, [spec((2,), "u32")],
            primitive="model", algo="cnn_datagen", direction="fwd",
            tags=("e2e",), params=cfg)

    # initial parameters as a constant-producing artifact (seeded init):
    def init_fn():
        return tuple(p[k] for k in model.PARAM_ORDER)

    em.emit("cnn_init-f32", init_fn, [],
            primitive="model", algo="cnn_init", direction="fwd",
            tags=("e2e",), params=cfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the artifact file exists")
    ap.add_argument("--only", default=None,
                    help="only emit artifacts whose signature contains this")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    em = Emitter(args.out, force=args.force, only=args.only)
    print("emitting conv family ...", flush=True)
    emit_conv_family(em)
    print("emitting fusion family ...", flush=True)
    emit_fusion_family(em)
    print("emitting primitives ...", flush=True)
    emit_primitives(em)
    print("emitting rnn family ...", flush=True)
    emit_rnn_family(em)
    print("emitting cnn ...", flush=True)
    emit_cnn(em)
    em.write_manifest()


if __name__ == "__main__":
    main()
