"""Direct convolution as a Pallas kernel (paper §IV-A, the "direct" algo).

MIOpen's direct algorithm is a family of hand-tuned GCN-assembly/OpenCL
kernels that compute the convolution without materializing im2col buffers.
The TPU adaptation (DESIGN.md §Hardware-Adaptation): each grid step owns an
output tile (one batch image × a K-tile of output channels), the filter
block and the input plane live in VMEM, and the R×S accumulation loop is
unrolled at trace time (R, S are compile-time constants, exactly like the
asm kernels specialize on filter size).

Tuning parameter (paper §III-B): `block_k` — the number of output channels
per grid step. The tuning grid is exported by `tuning_grid()`; aot.py emits
one artifact per variant so the Rust tuner can race them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, stride, dilation, r, s, ho, wo):
    """One (n, k-tile) output block.

    x_ref: (1, C, Hp, Wp) padded input plane   (VMEM)
    w_ref: (BK, C, R, S) filter block          (VMEM)
    o_ref: (1, BK, Ho, Wo) output tile         (VMEM)
    """
    xb = x_ref[0]  # (C, Hp, Wp)
    acc = jnp.zeros(o_ref.shape[1:], jnp.float32)
    for i in range(r):
        for j in range(s):
            di, dj = i * dilation[0], j * dilation[1]
            # Strided window of the input aligned with filter tap (i, j):
            # shape (C, Ho, Wo).
            xs = jax.lax.slice(
                xb,
                (0, di, dj),
                (xb.shape[0],
                 di + (ho - 1) * stride[0] + 1,
                 dj + (wo - 1) * stride[1] + 1),
                (1, stride[0], stride[1]),
            ).astype(jnp.float32)
            # (BK, C) x (C, Ho*Wo) — MXU-shaped contraction per tap.
            wt = w_ref[:, :, i, j].astype(jnp.float32)
            acc += jnp.einsum("kc,chw->khw", wt, xs,
                              preferred_element_type=jnp.float32)
    o_ref[0] = acc.astype(o_ref.dtype)


def conv2d_direct(x, w, *, stride=(1, 1), pad=(0, 0), dilation=(1, 1),
                  groups=1, block_k=16, out_dtype=None, interpret=True):
    """Direct Pallas convolution. x: (N,C,H,W), w: (K,C/g,R,S) -> (N,K,Ho,Wo).

    `out_dtype` overrides the output element type (int8 inputs accumulate
    exactly in f32 and emit f32, MIOpen's int8 output-conversion mode).
    """
    if groups != 1:
        return _grouped(x, w, stride=stride, pad=pad, dilation=dilation,
                        groups=groups, block_k=block_k, out_dtype=out_dtype,
                        interpret=interpret)
    out_dtype = out_dtype or x.dtype

    n, c, h, wd = x.shape
    k, cw, r, s = w.shape
    assert cw == c, f"channel mismatch {cw} != {c}"
    er = (r - 1) * dilation[0] + 1
    es = (s - 1) * dilation[1] + 1
    ho = (h + 2 * pad[0] - er) // stride[0] + 1
    wo = (wd + 2 * pad[1] - es) // stride[1] + 1

    xp = jnp.pad(x, ((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])))
    hp, wp = xp.shape[2], xp.shape[3]

    bk = min(block_k, k)
    kpad = (-k) % bk
    wpadded = jnp.pad(w, ((0, kpad), (0, 0), (0, 0), (0, 0)))
    ktiles = (k + kpad) // bk

    out = pl.pallas_call(
        functools.partial(_kernel, stride=stride, dilation=dilation,
                          r=r, s=s, ho=ho, wo=wo),
        grid=(n, ktiles),
        in_specs=[
            pl.BlockSpec((1, c, hp, wp), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((bk, c, r, s), lambda i, j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bk, ho, wo), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k + kpad, ho, wo), out_dtype),
        interpret=interpret,
    )(xp, wpadded)
    return out[:, :k]


def _grouped(x, w, *, stride, pad, dilation, groups, block_k, interpret,
             out_dtype=None):
    """Grouped/depthwise convolution: split along channels, convolve, stack.

    This mirrors the paper's definition of grouped convolution (§IV-A):
    depthwise is the groups == C special case.
    """
    n, c, _, _ = x.shape
    k = w.shape[0]
    assert c % groups == 0 and k % groups == 0
    cg, kg = c // groups, k // groups
    outs = []
    for g in range(groups):
        xg = x[:, g * cg : (g + 1) * cg]
        wg = w[g * kg : (g + 1) * kg]
        outs.append(conv2d_direct(xg, wg, stride=stride, pad=pad,
                                  dilation=dilation, groups=1,
                                  block_k=block_k, out_dtype=out_dtype,
                                  interpret=interpret))
    return jnp.concatenate(outs, axis=1)


def conv2d_direct_bwd_data(dy, w, x_shape, *, stride=(1, 1), pad=(0, 0),
                           dilation=(1, 1), block_k=16, interpret=True):
    """BackwardData as a forward direct conv over the dilated dy.

    dx = conv(dy dilated by `stride`, w rotated 180° and C<->K swapped),
    with padding (effective_filter - 1 - pad). Same trick the GCN direct
    bwd kernels use, so the Pallas kernel is reused as-is.
    """
    n, c, h, wd = x_shape
    k, cw, r, s = w.shape
    er = (r - 1) * dilation[0] + 1
    es = (s - 1) * dilation[1] + 1
    # dilate dy by stride
    dyd = _dilate(dy, stride)
    ph, pw = er - 1 - pad[0], es - 1 - pad[1]
    # When the stride does not divide (H + 2p - er) evenly, the dilated dy
    # is short of the rows/cols needed to produce all H input gradients;
    # zero-pad the bottom/right remainder (those inputs touch no output).
    extra_h = h - (dyd.shape[2] + 2 * ph - er + 1)
    extra_w = wd - (dyd.shape[3] + 2 * pw - es + 1)
    if extra_h > 0 or extra_w > 0:
        dyd = jnp.pad(dyd, ((0, 0), (0, 0),
                            (0, max(extra_h, 0)), (0, max(extra_w, 0))))
    # rotate + swap: (K,C,R,S) -> (C,K,R,S) flipped spatially
    wrot = jnp.flip(w, axis=(2, 3)).transpose(1, 0, 2, 3)
    dx = conv2d_direct(dyd, wrot, stride=(1, 1), pad=(ph, pw),
                       dilation=dilation, block_k=block_k,
                       interpret=interpret)
    # crop to x_shape (bottom/right may include extra rows when stride
    # doesn't divide the input size evenly)
    return dx[:, :, :h, :wd]


def conv2d_direct_bwd_weights(dy, x, w_shape, *, stride=(1, 1), pad=(0, 0),
                              dilation=(1, 1), block_k=16, interpret=True):
    """BackwardWeights: dw[k,c,i,j] = Σ_{n,oh,ow} dy·x(shifted by tap).

    Grid over the R·S filter taps; each step reduces over (N, Ho, Wo) with
    one GEMM-shaped contraction. Tap selection happens through the
    BlockSpec index map (the HBM→VMEM schedule), not inside the kernel.
    """
    del block_k
    k, c, r, s = w_shape
    n = x.shape[0]
    _, _, ho, wo = dy.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])))

    # per-tap window extent
    wh = (ho - 1) * stride[0] + 1
    ww = (wo - 1) * stride[1] + 1
    # Blocks must tile the array in pallas; gather per-tap windows up
    # front as a (R*S, N, C, wh, ww) tensor (pure data movement, XLA
    # fuses the slices), then grid over the leading axis.
    taps = []
    for i in range(r):
        for j in range(s):
            di, dj = i * dilation[0], j * dilation[1]
            taps.append(jax.lax.slice(
                xp, (0, 0, di, dj), (n, c, di + wh, dj + ww)))
    xtaps = jnp.stack(taps, axis=0)

    def kernel(dy_ref, xt_ref, o_ref):
        dyf = dy_ref[...].astype(jnp.float32)        # (N, K, Ho, Wo)
        xsw = jax.lax.slice(
            xt_ref[0], (0, 0, 0, 0), (n, c, wh, ww),
            (1, 1, stride[0], stride[1]),
        ).astype(jnp.float32)                        # (N, C, Ho, Wo)
        a = dyf.transpose(1, 0, 2, 3).reshape(k, -1)
        b = xsw.transpose(1, 0, 2, 3).reshape(c, -1)
        o_ref[0] = (a @ b.T).astype(o_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid=(r * s,),
        in_specs=[
            pl.BlockSpec((n, k, ho, wo), lambda t: (0, 0, 0, 0)),
            pl.BlockSpec((1, n, c, wh, ww), lambda t: (t, 0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, c), lambda t: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((r * s, k, c), x.dtype),
        interpret=interpret,
    )(dy, xtaps)
    return out.reshape(r, s, k, c).transpose(2, 3, 0, 1)


def _dilate(y, stride):
    """Insert stride-1 zeros between elements along H and W."""
    if stride == (1, 1):
        return y
    n, k, h, w = y.shape
    out = jnp.zeros((n, k, (h - 1) * stride[0] + 1, (w - 1) * stride[1] + 1),
                    y.dtype)
    return out.at[:, :, :: stride[0], :: stride[1]].set(y)


def tuning_grid(k):
    """Tuning-parameter grid for the direct solver (paper §III-B).

    block_k candidates, pruned to divisors-of-padded-K ≤ K (the pruned
    search space the paper describes).
    """
    cands = [4, 8, 16, 32, 64]
    return [b for b in cands if b <= max(k, 4)]


def vmem_bytes(c, hp, wp, bk, r, s, ho, wo, itemsize=4):
    """VMEM footprint of one grid step (used by the L1 perf estimate)."""
    return itemsize * (c * hp * wp + bk * c * r * s + bk * ho * wo)
