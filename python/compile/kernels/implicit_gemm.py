"""Implicit GEMM convolution — the composable-kernels algorithm (§IV-A).

MIOpen v2.0's composable-kernel implementation expresses convolution as a
GEMM whose A-matrix (the im2col patch matrix) is never materialized in
global memory: each workgroup gathers its patch tile on the fly into LDS
and feeds the MACs. The TPU adaptation: each grid step owns one batch
image × one K-tile; the kernel gathers the (Ho·Wo, C·R·S) patch matrix
*in VMEM* from the resident input plane and performs a single MXU-shaped
matmul against the (C·R·S, BK) filter tile.

Contrast with `direct.py`: direct accumulates per filter tap (R·S small
contractions); implicit GEMM builds the full patch matrix and issues one
large matmul — it trades VMEM for MXU occupancy, which is exactly the
trade the paper's composable kernels make with LDS.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, stride, dilation, r, s, ho, wo):
    """x_ref: (1,C,Hp,Wp), w_ref: (CRS, BK), o_ref: (1,BK,Ho,Wo)."""
    xb = x_ref[0]
    c = xb.shape[0]
    patches = []
    for i in range(r):
        for j in range(s):
            di, dj = i * dilation[0], j * dilation[1]
            xs = jax.lax.slice(
                xb,
                (0, di, dj),
                (c,
                 di + (ho - 1) * stride[0] + 1,
                 dj + (wo - 1) * stride[1] + 1),
                (1, stride[0], stride[1]),
            )  # (C, Ho, Wo)
            patches.append(xs.reshape(c, ho * wo))
    # (C, R*S, Ho*Wo) -> (Ho*Wo, C*R*S): C-major to match the filter reshape
    p = jnp.stack(patches, axis=1).reshape(c * r * s, ho * wo)
    a = p.T.astype(jnp.float32)            # (M=Ho*Wo, K=CRS)
    b = w_ref[...].astype(jnp.float32)     # (CRS, BK)
    acc = a @ b                            # one MXU matmul
    o_ref[0] = acc.T.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def conv2d_implicit_gemm(x, w, *, stride=(1, 1), pad=(0, 0),
                         dilation=(1, 1), block_k=32, interpret=True):
    """x: (N,C,H,W), w: (K,C,R,S) -> (N,K,Ho,Wo). Zero workspace."""
    n, c, h, wd = x.shape
    k, cw, r, s = w.shape
    assert cw == c
    er = (r - 1) * dilation[0] + 1
    es = (s - 1) * dilation[1] + 1
    ho = (h + 2 * pad[0] - er) // stride[0] + 1
    wo = (wd + 2 * pad[1] - es) // stride[1] + 1

    xp = jnp.pad(x, ((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])))
    hp, wp = xp.shape[2], xp.shape[3]

    bk = min(block_k, k)
    kpad = (-k) % bk
    # filter as (CRS, K+pad), C-major rows
    wmat = jnp.pad(w, ((0, kpad), (0, 0), (0, 0), (0, 0)))
    wmat = wmat.reshape(k + kpad, c * r * s).T

    out = pl.pallas_call(
        functools.partial(_kernel, stride=stride, dilation=dilation,
                          r=r, s=s, ho=ho, wo=wo),
        grid=(n, (k + kpad) // bk),
        in_specs=[
            pl.BlockSpec((1, c, hp, wp), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((c * r * s, bk), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bk, ho, wo), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k + kpad, ho, wo), x.dtype),
        interpret=interpret,
    )(xp, wmat)
    return out[:, :k]


def tuning_grid(k):
    cands = [8, 16, 32, 64, 128]
    return [b for b in cands if b <= max(k, 8)]
