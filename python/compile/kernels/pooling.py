"""Pooling Pallas kernels (paper §IV-D #2): max and average, fwd + bwd.

Grid over (N, C); each step owns one (H, W) plane in VMEM. The window loop
is unrolled at trace time exactly like the conv taps in direct.py.

Max-pool backward distributes the gradient to *every* element equal to the
window max (ties are measure-zero for float inputs; see DESIGN.md
§Known-limitations vs XLA's first-match SelectAndScatter).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _out_hw(h, w, window, stride, pad):
    ho = (h + 2 * pad[0] - window[0]) // stride[0] + 1
    wo = (w + 2 * pad[1] - window[1]) // stride[1] + 1
    return ho, wo


def _fwd_kernel(x_ref, y_ref, *, window, stride, ho, wo, mode):
    xb = x_ref[0, 0]  # (Hp, Wp)
    acc = None
    for i in range(window[0]):
        for j in range(window[1]):
            xs = jax.lax.slice(
                xb, (i, j),
                (i + (ho - 1) * stride[0] + 1, j + (wo - 1) * stride[1] + 1),
                (stride[0], stride[1]),
            ).astype(jnp.float32)
            acc = xs if acc is None else (
                jnp.maximum(acc, xs) if mode == "max" else acc + xs)
    if mode == "avg":
        acc = acc / (window[0] * window[1])
    y_ref[0, 0] = acc.astype(y_ref.dtype)


def pool2d_fwd(x, *, window=(2, 2), stride=(2, 2), pad=(0, 0), mode="max",
               interpret=True):
    n, c, h, w = x.shape
    ho, wo = _out_hw(h, w, window, stride, pad)
    fill = -jnp.inf if mode == "max" else 0.0
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])),
                 constant_values=fill)
    hp, wp = xp.shape[2], xp.shape[3]
    return pl.pallas_call(
        functools.partial(_fwd_kernel, window=window, stride=stride,
                          ho=ho, wo=wo, mode=mode),
        grid=(n, c),
        in_specs=[pl.BlockSpec((1, 1, hp, wp), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, ho, wo), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, ho, wo), x.dtype),
        interpret=interpret,
    )(xp)


def _bwd_kernel(x_ref, y_ref, dy_ref, dx_ref, *, window, stride, ho, wo, mode):
    """dx via scatter-back over the (unrolled) window taps."""
    xb = x_ref[0, 0].astype(jnp.float32)    # (Hp, Wp) padded input
    dy = dy_ref[0, 0].astype(jnp.float32)   # (Ho, Wo)
    dx = jnp.zeros_like(xb)
    if mode == "avg":
        g = dy / (window[0] * window[1])
    else:
        ymax = y_ref[0, 0].astype(jnp.float32)  # forward output = window max
    for i in range(window[0]):
        for j in range(window[1]):
            lims = (i + (ho - 1) * stride[0] + 1, j + (wo - 1) * stride[1] + 1)
            if mode == "max":
                xs = jax.lax.slice(xb, (i, j), lims, (stride[0], stride[1]))
                tap = jnp.where(xs == ymax, dy, 0.0)
            else:
                tap = g
            # scatter-add the tap back to the strided window positions
            cur = jax.lax.slice(dx, (i, j), lims, (stride[0], stride[1]))
            dx = jax.lax.dynamic_update_slice(
                dx,
                _strided_set(dx, cur + tap, (i, j), stride, lims),
                (0, 0),
            ) if False else _strided_add(dx, tap, (i, j), stride, lims)
    dx_ref[0, 0] = dx.astype(dx_ref.dtype)


def _strided_add(dx, tap, start, stride, lims):
    """dx[start0:lims0:stride0, start1:lims1:stride1] += tap (trace-time)."""
    return dx.at[start[0]:lims[0]:stride[0], start[1]:lims[1]:stride[1]].add(tap)


def pool2d_bwd(x, y, dy, *, window=(2, 2), stride=(2, 2), pad=(0, 0),
               mode="max", interpret=True):
    """x: fwd input, y: fwd output (MIOpen's bwd takes both), dy -> dx."""
    n, c, h, w = x.shape
    ho, wo = dy.shape[2], dy.shape[3]
    fill = -jnp.inf if mode == "max" else 0.0
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])),
                 constant_values=fill)
    hp, wp = xp.shape[2], xp.shape[3]
    dxp = pl.pallas_call(
        functools.partial(_bwd_kernel, window=window, stride=stride,
                          ho=ho, wo=wo, mode=mode),
        grid=(n, c),
        in_specs=[
            pl.BlockSpec((1, 1, hp, wp), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, ho, wo), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, ho, wo), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hp, wp), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, hp, wp), x.dtype),
        interpret=interpret,
    )(xp, y, dy)
    return dxp[:, :, pad[0] : pad[0] + h, pad[1] : pad[1] + w]
