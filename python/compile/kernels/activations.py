"""Activation Pallas kernels (paper §IV-D #1): the miopenActivationDescriptor
modes, forward and backward, as tiled elementwise kernels.

The mode is a compile-time constant (each mode is its own artifact, exactly
as MIOpen compiles one kernel per activation mode), so the kernel body is
branch-free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MODES = ("relu", "leaky_relu", "tanh", "sigmoid", "elu", "clipped_relu",
         "abs", "identity")


def _apply(x, mode, alpha):
    if mode == "relu":
        return jnp.maximum(x, 0.0)
    if mode == "leaky_relu":
        return jnp.where(x >= 0, x, alpha * x)
    if mode == "tanh":
        return jnp.tanh(x)
    if mode == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-x))
    if mode == "elu":
        return jnp.where(x >= 0, x, alpha * (jnp.exp(x) - 1.0))
    if mode == "clipped_relu":
        return jnp.clip(x, 0.0, alpha)
    if mode == "abs":
        return jnp.abs(x)
    if mode == "identity":
        return x
    raise ValueError(mode)


def _grad(x, mode, alpha):
    if mode == "relu":
        return jnp.where(x > 0, 1.0, 0.0)
    if mode == "leaky_relu":
        return jnp.where(x >= 0, 1.0, alpha)
    if mode == "tanh":
        t = jnp.tanh(x)
        return 1.0 - t * t
    if mode == "sigmoid":
        s = 1.0 / (1.0 + jnp.exp(-x))
        return s * (1.0 - s)
    if mode == "elu":
        return jnp.where(x >= 0, 1.0, alpha * jnp.exp(x))
    if mode == "clipped_relu":
        return jnp.where((x > 0) & (x < alpha), 1.0, 0.0)
    if mode == "abs":
        return jnp.sign(x)
    if mode == "identity":
        return jnp.ones_like(x)
    raise ValueError(mode)


def _tile(total, block):
    return (total + block - 1) // block


def _fwd_kernel(x_ref, y_ref, *, mode, alpha):
    y_ref[...] = _apply(x_ref[...].astype(jnp.float32), mode, alpha).astype(y_ref.dtype)


def activation_fwd(x, mode, alpha=0.0, *, block=4096, interpret=True):
    flat = x.reshape(-1)
    n = flat.shape[0]
    b = min(block, n)
    npad = (-n) % b
    fp = jnp.pad(flat, (0, npad))
    y = pl.pallas_call(
        functools.partial(_fwd_kernel, mode=mode, alpha=alpha),
        grid=(_tile(n + npad, b),),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(fp.shape, x.dtype),
        interpret=interpret,
    )(fp)
    return y[:n].reshape(x.shape)


def _bwd_kernel(x_ref, dy_ref, dx_ref, *, mode, alpha):
    x = x_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    dx_ref[...] = (dy * _grad(x, mode, alpha)).astype(dx_ref.dtype)


def activation_bwd(x, dy, mode, alpha=0.0, *, block=4096, interpret=True):
    flat_x = x.reshape(-1)
    flat_dy = dy.reshape(-1)
    n = flat_x.shape[0]
    b = min(block, n)
    npad = (-n) % b
    xp = jnp.pad(flat_x, (0, npad))
    dyp = jnp.pad(flat_dy, (0, npad))
    dx = pl.pallas_call(
        functools.partial(_bwd_kernel, mode=mode, alpha=alpha),
        grid=(_tile(n + npad, b),),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,)),
                  pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=interpret,
    )(xp, dyp)
    return dx[:n].reshape(x.shape)
