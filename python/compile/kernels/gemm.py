"""Tiled GEMM Pallas kernel — the MIOpenGEMM / rocBLAS analog.

Every GEMM in the library (im2col convolution, Winograd's elementwise
stage, RNN cell updates) routes through this kernel so all algorithms sit
on the same substrate (important for the fairness of Figure 6's relative
timings — see DESIGN.md §Substitutions).

Tiling: grid (M/bm, N/bn), accumulation loop over K tiles inside the
kernel. bm/bn/bk are tuning parameters in the paper's sense (§III-B); the
defaults are MXU-friendly multiples of 8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref, *, bk, ksize):
    """a_ref: (bm, K)  b_ref: (K, bn)  o_ref: (bm, bn)."""
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    steps = (ksize + bk - 1) // bk
    for t in range(steps):
        lo = t * bk
        hi = min(lo + bk, ksize)
        a = a_ref[:, lo:hi].astype(jnp.float32)
        b = b_ref[lo:hi, :].astype(jnp.float32)
        acc += a @ b
    o_ref[...] = acc.astype(o_ref.dtype)


def matmul(a, b, *, bm=32, bn=32, bk=128, out_dtype=None, interpret=True):
    """C = A @ B with A: (M, K), B: (K, N)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    out_dtype = out_dtype or a.dtype

    bm_, bn_ = min(bm, m), min(bn, n)
    mp, np_ = (-m) % bm_, (-n) % bn_
    ap = jnp.pad(a, ((0, mp), (0, 0)))
    bp = jnp.pad(b, ((0, 0), (0, np_)))

    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, ksize=k),
        grid=((m + mp) // bm_, (n + np_) // bn_),
        in_specs=[
            pl.BlockSpec((bm_, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn_), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + mp, n + np_), out_dtype),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


def batched_matmul(a, b, *, bm=32, bn=32, bk=128, out_dtype=None,
                   interpret=True):
    """C[g] = A[g] @ B[g] for g in the leading axis (Winograd's 16 stages)."""
    g, m, k = a.shape
    g2, k2, n = b.shape
    assert g == g2 and k == k2
    out_dtype = out_dtype or a.dtype

    bm_, bn_ = min(bm, m), min(bn, n)
    mp, np_ = (-m) % bm_, (-n) % bn_
    ap = jnp.pad(a, ((0, 0), (0, mp), (0, 0)))
    bp = jnp.pad(b, ((0, 0), (0, 0), (0, np_)))

    def kern(a_ref, b_ref, o_ref):
        acc = jnp.zeros(o_ref.shape[1:], jnp.float32)
        steps = (k + bk - 1) // bk
        for t in range(steps):
            lo, hi = t * bk, min(t * bk + bk, k)
            acc += a_ref[0, :, lo:hi].astype(jnp.float32) @ \
                   b_ref[0, lo:hi, :].astype(jnp.float32)
        o_ref[0] = acc.astype(o_ref.dtype)

    out = pl.pallas_call(
        kern,
        grid=(g, (m + mp) // bm_, (n + np_) // bn_),
        in_specs=[
            pl.BlockSpec((1, bm_, k), lambda gi, i, j: (gi, i, 0)),
            pl.BlockSpec((1, k, bn_), lambda gi, i, j: (gi, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn_), lambda gi, i, j: (gi, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, m + mp, n + np_), out_dtype),
        interpret=interpret,
    )(ap, bp)
    return out[:, :m, :n]


def tuning_grid(m, n):
    """(bm, bn) tuning candidates, pruned to the problem size."""
    cands = [(16, 16), (32, 32), (64, 64), (32, 128), (128, 32)]
    return [(a, b) for (a, b) in cands if a <= max(m, 16) and b <= max(n, 16)]
