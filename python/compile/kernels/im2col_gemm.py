"""im2col + GEMM convolution — the paper's baseline algorithm (§IV-A).

"The most general and arguably most expensive in terms of additional
storage": the input is unfolded into a (C·R·S, Ho·Wo) column matrix (the
*workspace* the find step reports), then a single GEMM with the (K, C·R·S)
filter matrix produces the output. The unfold happens in jnp (it is pure
data movement); the GEMM goes through the Pallas `gemm` kernel so the
baseline shares the solvers' substrate.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import gemm
from .ref import im2col


def conv2d_im2col(x, w, *, stride=(1, 1), pad=(0, 0), dilation=(1, 1),
                  bm=64, bn=1024, interpret=True):
    """x: (N,C,H,W), w: (K,C,R,S) -> (N,K,Ho,Wo)."""
    n = x.shape[0]
    k, c, r, s = w.shape
    col, (ho, wo) = im2col(x, r, s, stride=stride, pad=pad, dilation=dilation)
    # One GEMM over the whole batch: (K, CRS) @ (CRS, N*Ho*Wo)
    a = w.reshape(k, c * r * s)
    b = col.transpose(1, 0, 2).reshape(c * r * s, n * ho * wo)
    out = gemm.matmul(a, b, bm=bm, bn=bn, interpret=interpret)
    return out.reshape(k, n, ho, wo).transpose(1, 0, 2, 3)


def conv2d_im2col_bwd_data(dy, w, x_shape, *, stride=(1, 1), pad=(0, 0),
                           dilation=(1, 1), bm=64, bn=1024, interpret=True):
    """BackwardData baseline: col = Wᵀ·dy (GEMM), then col2im scatter-add."""
    n, c, h, wd = x_shape
    k, cw, r, s = w.shape
    _, _, ho, wo = dy.shape
    # (CRS, K) @ (K, N*Ho*Wo) -> (CRS, N*Ho*Wo)
    a = w.reshape(k, c * r * s).T
    b = dy.transpose(1, 0, 2, 3).reshape(k, n * ho * wo)
    col = gemm.matmul(a, b, bm=bm, bn=bn, interpret=interpret)
    col = col.reshape(c, r * s, n, ho, wo)

    hp, wp = h + 2 * pad[0], wd + 2 * pad[1]
    dxp = jnp.zeros((n, c, hp, wp), dy.dtype)
    idx = 0
    for i in range(r):
        for j in range(s):
            di, dj = i * dilation[0], j * dilation[1]
            patch = col[:, idx].transpose(1, 0, 2, 3)  # (N, C, Ho, Wo)
            dxp = dxp.at[:, :,
                         di : di + (ho - 1) * stride[0] + 1 : stride[0],
                         dj : dj + (wo - 1) * stride[1] + 1 : stride[1]].add(patch)
            idx += 1
    return dxp[:, :, pad[0] : pad[0] + h, pad[1] : pad[1] + wd]


def conv2d_im2col_bwd_weights(dy, x, w_shape, *, stride=(1, 1), pad=(0, 0),
                              dilation=(1, 1), bm=64, bn=256, interpret=True):
    """BackwardWeights baseline: dW = dy·colᵀ (GEMM over N·Ho·Wo)."""
    k, c, r, s = w_shape
    n = x.shape[0]
    col, (ho, wo) = im2col(x, r, s, stride=stride, pad=pad, dilation=dilation)
    # (K, N*Ho*Wo) @ (N*Ho*Wo, CRS)
    a = dy.transpose(1, 0, 2, 3).reshape(k, n * ho * wo)
    b = col.transpose(1, 0, 2).reshape(c * r * s, n * ho * wo).T
    dw = gemm.matmul(a, b, bm=bm, bn=bn, interpret=interpret)
    return dw.reshape(k, c, r, s)


# Blocked-engine microkernel strips (mirror of gemm::MR / gemm::NR in
# rust/src/runtime/interp/gemm.rs — the packed-panel padding below must
# match the executing engine's).
GEMM_MR = 4
GEMM_NR = 16


def workspace_bytes(x_shape, w_shape, out_shape, itemsize=4):
    """Arena-aware workspace the find step reports for this algorithm:
    the per-image im2col column matrix plus the blocked engine's packed
    A (weights, MR-strip padded) and packed B (col matrix, NR-strip
    padded) panels. Per-image buffers are reused across the batch by the
    workspace arena, so N does not multiply in (mirrors
    GemmSolver::workspace_bytes on the Rust side)."""
    _, c, _, _ = x_shape
    k, _, r, s = w_shape
    _, _, ho, wo = out_shape
    crs = c * r * s
    howo = ho * wo
    pa = -(-k // GEMM_MR) * GEMM_MR * crs
    pb = -(-howo // GEMM_NR) * GEMM_NR * crs
    return itemsize * (crs * howo + pa + pb)
