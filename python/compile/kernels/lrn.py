"""Cross-channel Local Response Normalization (paper §IV-D #6).

AlexNet-style LRN: y = x / (k + alpha/n * sum_{window} x^2)^beta with the
window sliding over channels. Grid over N; the channel window loop is
unrolled (n is a small compile-time constant, typically 5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, *, n, alpha, beta, k, c):
    x = x_ref[0].astype(jnp.float32)  # (C, H, W)
    half = n // 2
    sq = x * x
    padded = jnp.pad(sq, ((half, half), (0, 0), (0, 0)))
    win = padded[0:c]
    for i in range(1, n):
        win = win + padded[i : i + c]
    denom = (k + (alpha / n) * win) ** beta
    y_ref[0] = (x / denom).astype(y_ref.dtype)


def lrn_fwd(x, *, n=5, alpha=1e-4, beta=0.75, k=2.0, interpret=True):
    nb, c, h, w = x.shape
    return pl.pallas_call(
        functools.partial(_kernel, n=n, alpha=alpha, beta=beta, k=k, c=c),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
