"""FFT convolution (§IV-A): for large filters, transform image and filter
to the frequency domain, multiply point-wise, transform back.

Pure-jnp implementation: Pallas has no complex-number support, so the FFT
algorithm lives entirely in the L2 graph (DESIGN.md §Known-limitations).
It is still a first-class solver — AOT'd per config, raced by the find
step, costed by the perf model (where its win over direct on big R×S comes
from the O(HW log HW) vs O(HW·RS) term).

The paper notes the filter transform is paid once when reused; the AOT
artifact keeps the filter transform inside (stateless API), and the rust
solver's perf model credits the amortized case separately.
"""

from __future__ import annotations

import jax.numpy as jnp


def conv2d_fft(x, w, *, stride=(1, 1), pad=(0, 0)):
    """x: (N,C,H,W), w: (K,C,R,S) -> (N,K,Ho,Wo). Cross-correlation."""
    n, c, h, wd = x.shape
    k, cw, r, s = w.shape
    assert cw == c

    xp = jnp.pad(x, ((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])))
    hp, wp = xp.shape[2], xp.shape[3]
    ho = (hp - r) // stride[0] + 1
    wo = (wp - s) // stride[1] + 1

    # FFT size: linear-convolution-safe padded extent.
    fh = hp + r - 1
    fw = wp + s - 1

    xf = jnp.fft.rfft2(xp.astype(jnp.float32), s=(fh, fw))
    # Cross-correlation == convolution with the flipped filter; flip here so
    # the pointwise product in frequency space yields cross-correlation.
    wf = jnp.fft.rfft2(jnp.flip(w.astype(jnp.float32), (2, 3)), s=(fh, fw))

    # (N,1,C,fh,fw̃) * (1,K,C,fh,fw̃) summed over C
    yf = jnp.einsum("nchw,kchw->nkhw", xf, wf)
    y = jnp.fft.irfft2(yf, s=(fh, fw))

    # 'valid' region of the correlation starts at offset (r-1, s-1)
    y = y[:, :, r - 1 : r - 1 + (ho - 1) * stride[0] + 1 : stride[0],
          s - 1 : s - 1 + (wo - 1) * stride[1] + 1 : stride[1]]
    return y.astype(x.dtype)


def _next_pow2(x):
    n = 1
    while n < x:
        n <<= 1
    return n


def workspace_bytes(x_shape, w_shape, pad=(0, 0), itemsize=8):
    """Frequency-domain buffers the find step reports: complex spectra for
    X (N·C), W (K·C) and Y (N·K) over the power-of-two-padded planes the
    reference radix-2 executor uses (mirrors FftSolver::workspace_bytes)."""
    n, c, h, wd = x_shape
    k, _, r, s = w_shape
    fh = _next_pow2(h + 2 * pad[0] + r - 1)
    fw = _next_pow2(wd + 2 * pad[1] + s - 1)
    return itemsize * fh * fw * (n * c + k * c + n * k)
