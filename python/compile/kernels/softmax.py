"""Softmax / LogSoftmax Pallas kernels (paper §IV-D #3).

MIOpen's softmax operates over the channel axis of an NCHW tensor. Grid
over N; each step reduces the (C,H,W) slab in VMEM with the numerically
stable max-shift.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, y_ref, *, log):
    x = x_ref[0].astype(jnp.float32)                 # (C,H,W)
    m = jnp.max(x, axis=0, keepdims=True)
    e = jnp.exp(x - m)
    z = jnp.sum(e, axis=0, keepdims=True)
    if log:
        y = (x - m) - jnp.log(z)
    else:
        y = e / z
    y_ref[0] = y.astype(y_ref.dtype)


def softmax_fwd(x, *, log=False, interpret=True):
    n, c, h, w = x.shape
    return pl.pallas_call(
        functools.partial(_fwd_kernel, log=log),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def _bwd_kernel(y_ref, dy_ref, dx_ref, *, log):
    y = y_ref[0].astype(jnp.float32)
    dy = dy_ref[0].astype(jnp.float32)
    if log:
        dx = dy - jnp.exp(y) * jnp.sum(dy, axis=0, keepdims=True)
    else:
        dx = y * (dy - jnp.sum(dy * y, axis=0, keepdims=True))
    dx_ref[0] = dx.astype(dx_ref.dtype)


def softmax_bwd(y, dy, *, log=False, interpret=True):
    """Backward from the forward *output* (MIOpen convention)."""
    n, c, h, w = y.shape
    blk = lambda: pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0))
    return pl.pallas_call(
        functools.partial(_bwd_kernel, log=log),
        grid=(n,),
        in_specs=[blk(), blk()],
        out_specs=blk(),
        out_shape=jax.ShapeDtypeStruct(y.shape, y.dtype),
        interpret=interpret,
    )(y, dy)
