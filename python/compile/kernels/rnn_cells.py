"""RNN cell kernels and sequence assemblies (paper §IV-C, eqs. 1–21).

The paper's optimization: (a) all four LSTM gate pre-activations for all
timesteps share one input GEMM (eq. 12) because x_t are time-independent;
(b) per step, the four hidden-state GEMMs collapse into one (eq. 11); and
(c) the gate nonlinearities (eqs. 5–8) fuse into a single kernel thanks to
"computational homogeneity and contiguous memory-layout".

Here (a)/(b) are the fused-GEMM assemblies below (GEMMs on the Pallas
`gemm` substrate inside a `lax.scan`), and (c) is the fused pointwise
Pallas kernel `lstm_pointwise` that turns s=[si|sf|so|sc̃] + c_{t-1} into
(h_t, c_t) in one pass. `lstm_seq_naive` keeps the textbook layout —
separate GEMM + separate activation per gate per step — as the ablation
baseline (bench `abl-rnn`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import gemm


# -- fused pointwise gate kernels -------------------------------------------

def _lstm_pointwise_kernel(s_ref, c_ref, h_ref, cout_ref, *, hidden):
    s = s_ref[...].astype(jnp.float32)          # (B, 4H), [i|f|o|c~]
    c_prev = c_ref[...].astype(jnp.float32)     # (B, H)
    si = s[:, 0 * hidden : 1 * hidden]
    sf = s[:, 1 * hidden : 2 * hidden]
    so = s[:, 2 * hidden : 3 * hidden]
    sc = s[:, 3 * hidden : 4 * hidden]
    sig = lambda t: 1.0 / (1.0 + jnp.exp(-t))
    i, f, o = sig(si), sig(sf), sig(so)
    cbar = jnp.tanh(sc)
    c_t = f * c_prev + i * cbar
    h_t = o * jnp.tanh(c_t)
    h_ref[...] = h_t.astype(h_ref.dtype)
    cout_ref[...] = c_t.astype(cout_ref.dtype)


def lstm_pointwise(s, c_prev, *, interpret=True):
    """s: (B, 4H) fused pre-activations, c_prev: (B, H) -> (h_t, c_t)."""
    b, four_h = s.shape
    hidden = four_h // 4
    return pl.pallas_call(
        functools.partial(_lstm_pointwise_kernel, hidden=hidden),
        grid=(1,),
        in_specs=[pl.BlockSpec((b, four_h), lambda i: (0, 0)),
                  pl.BlockSpec((b, hidden), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((b, hidden), lambda i: (0, 0)),
                   pl.BlockSpec((b, hidden), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, hidden), s.dtype),
                   jax.ShapeDtypeStruct((b, hidden), s.dtype)],
        interpret=interpret,
    )(s, c_prev)


def _gru_pointwise_kernel(sx_ref, sh_ref, h_ref, hout_ref, *, hidden):
    sx = sx_ref[...].astype(jnp.float32)   # (B, 3H), [r|z|n]
    sh = sh_ref[...].astype(jnp.float32)
    h_prev = h_ref[...].astype(jnp.float32)
    sig = lambda t: 1.0 / (1.0 + jnp.exp(-t))
    r = sig(sx[:, :hidden] + sh[:, :hidden])
    z = sig(sx[:, hidden : 2 * hidden] + sh[:, hidden : 2 * hidden])
    n = jnp.tanh(sx[:, 2 * hidden :] + r * sh[:, 2 * hidden :])
    hout_ref[...] = ((1.0 - z) * n + z * h_prev).astype(hout_ref.dtype)


def gru_pointwise(sx, sh, h_prev, *, interpret=True):
    b, three_h = sx.shape
    hidden = three_h // 3
    return pl.pallas_call(
        functools.partial(_gru_pointwise_kernel, hidden=hidden),
        grid=(1,),
        in_specs=[pl.BlockSpec((b, three_h), lambda i: (0, 0)),
                  pl.BlockSpec((b, three_h), lambda i: (0, 0)),
                  pl.BlockSpec((b, hidden), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((b, hidden), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hidden), sx.dtype),
        interpret=interpret,
    )(sx, sh, h_prev)


def _vanilla_pointwise_kernel(s_ref, h_ref, *, act):
    s = s_ref[...].astype(jnp.float32)
    h = jnp.tanh(s) if act == "tanh" else jnp.maximum(s, 0.0)
    h_ref[...] = h.astype(h_ref.dtype)


def vanilla_pointwise(s, *, act="tanh", interpret=True):
    b, hidden = s.shape
    return pl.pallas_call(
        functools.partial(_vanilla_pointwise_kernel, act=act),
        grid=(1,),
        in_specs=[pl.BlockSpec((b, hidden), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((b, hidden), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hidden), s.dtype),
        interpret=interpret,
    )(s)


# -- fused-GEMM sequence assemblies (the paper's optimization) ---------------

def lstm_seq_fused(xs, h0, c0, W, R, b=None, *, interpret=True):
    """Eqs. 11–12: ONE input GEMM for all T, one hidden GEMM + one fused
    pointwise kernel per step.

    xs: (T, B, X); W: (4H, X); R: (4H, H) -> hs: (T, B, H).
    """
    T, B, X = xs.shape
    H4 = W.shape[0]
    # eq. 12: [s_0 ... s_{T-1}] = W [x_0 ... x_{T-1}] — one GEMM, weights
    # loaded once for the whole sequence.
    sx_all = gemm.matmul(xs.reshape(T * B, X), W.T,
                         interpret=interpret).reshape(T, B, H4)
    if b is not None:
        sx_all = sx_all + b

    def step(carry, sx_t):
        h, c = carry
        # eq. 11: one GEMM for all four gates' hidden contribution.
        sh = gemm.matmul(h, R.T, interpret=interpret)
        h2, c2 = lstm_pointwise(sx_t + sh, c, interpret=interpret)
        return (h2, c2), h2

    (_, _), hs = jax.lax.scan(step, (h0, c0), sx_all)
    return hs


def lstm_seq_naive(xs, h0, c0, W, R, b=None, *, interpret=True):
    """Ablation baseline: per-gate GEMMs (4 + 4 per step, eq. 1–4 verbatim)
    and per-gate activation kernels (eqs. 5–8 unfused)."""
    T, B, X = xs.shape
    H = R.shape[1]
    Ws = jnp.split(W, 4, axis=0)
    Rs = jnp.split(R, 4, axis=0)
    bs = jnp.split(b, 4) if b is not None else [None] * 4

    def step(carry, x_t):
        h, c = carry
        pre = []
        for Wg, Rg, bg in zip(Ws, Rs, bs):
            s = gemm.matmul(x_t, Wg.T, interpret=interpret) + \
                gemm.matmul(h, Rg.T, interpret=interpret)
            if bg is not None:
                s = s + bg
            pre.append(s)
        si, sf, so, sc = pre
        sig = lambda t: 1.0 / (1.0 + jnp.exp(-t))
        i, f, o = sig(si), sig(sf), sig(so)      # separate kernels in MIOpen
        cbar = jnp.tanh(sc)
        c2 = f * c + i * cbar
        h2 = o * jnp.tanh(c2)
        return (h2, c2), h2

    (_, _), hs = jax.lax.scan(step, (h0, c0), xs)
    return hs


def gru_seq_fused(xs, h0, W, R, b=None, *, interpret=True):
    """GRU with the same eq.-12 treatment. b = (bx, bh) if given."""
    T, B, X = xs.shape
    H3 = W.shape[0]
    sx_all = gemm.matmul(xs.reshape(T * B, X), W.T,
                         interpret=interpret).reshape(T, B, H3)
    if b is not None:
        sx_all = sx_all + b[0]

    def step(h, sx_t):
        sh = gemm.matmul(h, R.T, interpret=interpret)
        if b is not None:
            sh = sh + b[1]
        h2 = gru_pointwise(sx_t, sh, h, interpret=interpret)
        return h2, h2

    _, hs = jax.lax.scan(step, h0, sx_all)
    return hs


def vanilla_seq_fused(xs, h0, W, R, b=None, *, act="tanh", interpret=True):
    T, B, X = xs.shape
    H = W.shape[0]
    sx_all = gemm.matmul(xs.reshape(T * B, X), W.T,
                         interpret=interpret).reshape(T, B, H)
    if b is not None:
        sx_all = sx_all + b

    def step(h, sx_t):
        s = sx_t + gemm.matmul(h, R.T, interpret=interpret)
        h2 = vanilla_pointwise(s, act=act, interpret=interpret)
        return h2, h2

    _, hs = jax.lax.scan(step, h0, sx_all)
    return hs


def bidirectional(seq_fn, xs, *args, **kwargs):
    """miopenRNNbidirection: forward pass + reversed pass, concatenated on
    the hidden axis (MIOpen's layout)."""
    fwd = seq_fn(xs, *args, **kwargs)
    bwd = seq_fn(jnp.flip(xs, axis=0), *args, **kwargs)
    return jnp.concatenate([fwd, jnp.flip(bwd, axis=0)], axis=-1)
