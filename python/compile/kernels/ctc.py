"""CTC loss (paper §IV-D #4) — batched, scan-based, AOT-compatible.

Log-space alpha recursion over the extended (blank-interleaved) label
sequence, vectorized over the batch with static padded label length. The
python-loop oracle lives in ref.py; this version lowers cleanly through
`jax.jit` for the artifact path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ctc_loss(log_probs, labels, input_lens, label_lens, blank=0):
    """Batched CTC negative log-likelihood.

    log_probs: (B, T, V) log-softmax outputs
    labels:    (B, L) padded label ids (no blanks)
    input_lens/label_lens: (B,) actual lengths
    Returns (B,) losses.
    """
    B, T, V = log_probs.shape
    L = labels.shape[1]
    S = 2 * L + 1

    # extended sequence: [blank, l1, blank, l2, ..., blank]
    ext = jnp.full((B, S), blank, labels.dtype)
    ext = ext.at[:, 1::2].set(labels)

    # allowed skip: ext[s] != blank and ext[s] != ext[s-2]
    skip_ok = jnp.zeros((B, S), bool)
    skip_ok = skip_ok.at[:, 2:].set(
        (ext[:, 2:] != blank) & (ext[:, 2:] != ext[:, :-2]))

    batch_idx = jnp.arange(B)[:, None]

    def emit(t):
        # log_probs[b, t, ext[b, s]] -> (B, S)
        return log_probs[batch_idx, t, ext]

    alpha0 = jnp.full((B, S), NEG_INF)
    alpha0 = alpha0.at[:, 0].set(log_probs[:, 0, blank])
    alpha0 = alpha0.at[:, 1].set(
        jnp.where(label_lens > 0, log_probs[batch_idx[:, 0], 0, ext[:, 1]],
                  NEG_INF))

    def step(alpha, t):
        a0 = alpha
        a1 = jnp.concatenate([jnp.full((B, 1), NEG_INF), alpha[:, :-1]], 1)
        a2 = jnp.concatenate([jnp.full((B, 2), NEG_INF), alpha[:, :-2]], 1)
        a2 = jnp.where(skip_ok, a2, NEG_INF)
        m = jnp.maximum(jnp.maximum(a0, a1), a2)
        msafe = jnp.where(m <= NEG_INF / 2, 0.0, m)
        tot = msafe + jnp.log(
            jnp.exp(a0 - msafe) + jnp.exp(a1 - msafe) + jnp.exp(a2 - msafe))
        tot = jnp.where(m <= NEG_INF / 2, NEG_INF, tot)
        new = tot + emit(t)
        # freeze past each sequence's end
        new = jnp.where((t < input_lens)[:, None], new, alpha)
        return new, None

    alpha, _ = jax.lax.scan(step, alpha0, jnp.arange(1, T))

    send = 2 * label_lens     # index of final blank
    send_m1 = send - 1        # final label
    a_last = alpha[batch_idx[:, 0], send]
    a_prev = jnp.where(label_lens > 0,
                       alpha[batch_idx[:, 0], send_m1], NEG_INF)
    m = jnp.maximum(a_last, a_prev)
    msafe = jnp.where(m <= NEG_INF / 2, 0.0, m)
    ll = msafe + jnp.log(jnp.exp(a_last - msafe) + jnp.exp(a_prev - msafe))
    return -ll
