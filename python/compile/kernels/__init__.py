"""MIOpen-rs L1 kernels: Pallas implementations of the paper's primitives."""
