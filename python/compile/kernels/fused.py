"""Fused kernels for the Fusion API (paper §V).

Each supported fusion combination (Tables I/II) gets a single Pallas kernel
that keeps the intermediate in VMEM — the on-chip-memory argument of §V:

  CBA  — Conv + Bias + Activation          (Figure 7a)
  NA   — BatchNorm (inference) + Activation (Figure 7b)
  CBNA — Conv + Bias + BatchNorm + Activation

The conv stage reuses direct.py's per-tap accumulation; bias/normalize/
activate are applied to the accumulator before the single write-back, so
global-memory traffic drops from (write + read) per stage to one write.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .activations import _apply


def _cba_kernel(x_ref, w_ref, b_ref, o_ref, *, stride, r, s, ho, wo,
                mode, alpha):
    xb = x_ref[0]
    acc = jnp.zeros(o_ref.shape[1:], jnp.float32)
    for i in range(r):
        for j in range(s):
            xs = jax.lax.slice(
                xb, (0, i, j),
                (xb.shape[0],
                 i + (ho - 1) * stride[0] + 1,
                 j + (wo - 1) * stride[1] + 1),
                (1, stride[0], stride[1]),
            ).astype(jnp.float32)
            wt = w_ref[:, :, i, j].astype(jnp.float32)
            acc += jnp.einsum("kc,chw->khw", wt, xs,
                              preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)[:, None, None]   # bias
    acc = _apply(acc, mode, alpha)                              # activation
    o_ref[0] = acc.astype(o_ref.dtype)


def conv_bias_act(x, w, bias, *, stride=(1, 1), pad=(0, 0), mode="relu",
                  alpha=0.0, block_k=16, interpret=True):
    """Fused CBA: one kernel, one write-back. x NCHW, w KCRS, bias (K,)."""
    n, c, h, wd = x.shape
    k, cw, r, s = w.shape
    assert cw == c
    ho = (h + 2 * pad[0] - r) // stride[0] + 1
    wo = (wd + 2 * pad[1] - s) // stride[1] + 1
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])))
    hp, wp = xp.shape[2], xp.shape[3]

    bk = min(block_k, k)
    kpad = (-k) % bk
    wpad = jnp.pad(w, ((0, kpad), (0, 0), (0, 0), (0, 0)))
    bpad = jnp.pad(bias, (0, kpad))

    out = pl.pallas_call(
        functools.partial(_cba_kernel, stride=stride, r=r, s=s, ho=ho,
                          wo=wo, mode=mode, alpha=alpha),
        grid=(n, (k + kpad) // bk),
        in_specs=[
            pl.BlockSpec((1, c, hp, wp), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((bk, c, r, s), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((bk,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, bk, ho, wo), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k + kpad, ho, wo), x.dtype),
        interpret=interpret,
    )(xp, wpad, bpad)
    return out[:, :k]


def conv_bias_act_winograd(x, w, bias, *, pad=(1, 1), mode="relu",
                           alpha=0.0, interpret=True):
    """Fused CBA whose conv stage is the Winograd F(2,3) pipeline (the
    Table I winograd rows): bias + activation ride on the inverse
    transform's output before the single write-back."""
    from .winograd import conv2d_winograd

    y = conv2d_winograd(x, w, pad=pad, interpret=interpret)
    y = y.astype(jnp.float32) + bias.astype(jnp.float32)[None, :, None, None]
    return _apply(y, mode, alpha).astype(x.dtype)


def _bn_act_kernel(x_ref, g_ref, b_ref, m_ref, v_ref, y_ref, *, eps, mode,
                   alpha):
    x = x_ref[...].astype(jnp.float32)
    inv = jax.lax.rsqrt(v_ref[0] + eps)
    y = g_ref[0] * (x - m_ref[0]) * inv + b_ref[0]
    y_ref[...] = _apply(y, mode, alpha).astype(y_ref.dtype)


def bn_act(x, gamma, beta, mean, var, *, eps=1e-5, mode="relu", alpha=0.0,
           interpret=True):
    """Fused NA (spatial BN inference + activation), Figure 7b's fused arm."""
    n, c, h, w = x.shape
    vec = lambda: pl.BlockSpec((1,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_bn_act_kernel, eps=eps, mode=mode, alpha=alpha),
        grid=(c,),
        in_specs=[pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
                  vec(), vec(), vec(), vec()],
        out_specs=pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, gamma, beta, mean, var)


def _cbna_kernel(x_ref, w_ref, bias_ref, g_ref, b_ref, m_ref, v_ref, o_ref,
                 *, stride, r, s, ho, wo, eps, mode, alpha):
    xb = x_ref[0]
    acc = jnp.zeros(o_ref.shape[1:], jnp.float32)
    for i in range(r):
        for j in range(s):
            xs = jax.lax.slice(
                xb, (0, i, j),
                (xb.shape[0],
                 i + (ho - 1) * stride[0] + 1,
                 j + (wo - 1) * stride[1] + 1),
                (1, stride[0], stride[1]),
            ).astype(jnp.float32)
            wt = w_ref[:, :, i, j].astype(jnp.float32)
            acc += jnp.einsum("kc,chw->khw", wt, xs,
                              preferred_element_type=jnp.float32)
    acc = acc + bias_ref[...].astype(jnp.float32)[:, None, None]
    inv = jax.lax.rsqrt(v_ref[...].astype(jnp.float32) + eps)
    acc = g_ref[...].astype(jnp.float32)[:, None, None] * \
        (acc - m_ref[...].astype(jnp.float32)[:, None, None]) * \
        inv[:, None, None] + b_ref[...].astype(jnp.float32)[:, None, None]
    o_ref[0] = _apply(acc, mode, alpha).astype(o_ref.dtype)


def conv_bias_bn_act(x, w, bias, gamma, beta, mean, var, *, stride=(1, 1),
                     pad=(0, 0), eps=1e-5, mode="relu", alpha=0.0,
                     block_k=16, interpret=True):
    """Fused CBNA (Tables I/II row 1): conv + bias + BN(inference) + act."""
    n, c, h, wd = x.shape
    k, cw, r, s = w.shape
    assert cw == c
    ho = (h + 2 * pad[0] - r) // stride[0] + 1
    wo = (wd + 2 * pad[1] - s) // stride[1] + 1
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])))
    hp, wp = xp.shape[2], xp.shape[3]

    bk = min(block_k, k)
    kpad = (-k) % bk
    pk = lambda t: jnp.pad(t, (0, kpad))
    wpad = jnp.pad(w, ((0, kpad), (0, 0), (0, 0), (0, 0)))
    # pad var with ones to keep rsqrt finite in the dead K-tail
    vpad = jnp.pad(var, (0, kpad), constant_values=1.0)

    vecs = [pk(bias), pk(gamma), pk(beta), pk(mean), vpad]
    vspec = lambda: pl.BlockSpec((bk,), lambda i, j: (j,))
    out = pl.pallas_call(
        functools.partial(_cbna_kernel, stride=stride, r=r, s=s, ho=ho,
                          wo=wo, eps=eps, mode=mode, alpha=alpha),
        grid=(n, (k + kpad) // bk),
        in_specs=[
            pl.BlockSpec((1, c, hp, wp), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((bk, c, r, s), lambda i, j: (j, 0, 0, 0)),
            vspec(), vspec(), vspec(), vspec(), vspec(),
        ],
        out_specs=pl.BlockSpec((1, bk, ho, wo), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k + kpad, ho, wo), x.dtype),
        interpret=interpret,
    )(xp, wpad, *vecs)
    return out[:, :k]
