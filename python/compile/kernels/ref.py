"""Pure-jnp reference oracles for every MIOpen primitive.

These are the correctness ground truth for the Pallas kernels (L1) and the
fused/RNN compositions (L2). Everything here is written for clarity, not
speed: straightforward `lax.conv_general_dilated` / explicit loops in
`lax.scan`, matching the operator definitions in the MIOpen paper §IV.

Layout conventions (MIOpen defaults):
  activations: NCHW   filters: KCRS (K = output channels, R×S filter)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Convolution (paper §IV-A)
# ---------------------------------------------------------------------------


def conv2d_fwd(x, w, *, stride=(1, 1), pad=(0, 0), dilation=(1, 1), groups=1):
    """Forward convolution. x: (N,C,H,W)  w: (K,C/g,R,S) -> (N,K,Ho,Wo).

    This is MIOpen's cross-correlation convention (`miopenConvolution`):
    no filter flip.
    """
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=stride,
        padding=[(pad[0], pad[0]), (pad[1], pad[1])],
        rhs_dilation=dilation,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def conv2d_bwd_data(dy, w, x_shape, *, stride=(1, 1), pad=(0, 0), dilation=(1, 1), groups=1):
    """Gradient w.r.t. the input (MIOpen BackwardData direction)."""

    def f(x):
        return conv2d_fwd(x, w, stride=stride, pad=pad, dilation=dilation, groups=groups)

    x0 = jnp.zeros(x_shape, dy.dtype)
    _, vjp = jax.vjp(f, x0)
    return vjp(dy)[0]


def conv2d_bwd_weights(dy, x, w_shape, *, stride=(1, 1), pad=(0, 0), dilation=(1, 1), groups=1):
    """Gradient w.r.t. the filter (MIOpen BackwardWeights direction)."""

    def f(w):
        return conv2d_fwd(x, w, stride=stride, pad=pad, dilation=dilation, groups=groups)

    w0 = jnp.zeros(w_shape, dy.dtype)
    _, vjp = jax.vjp(f, w0)
    return vjp(dy)[0]


def conv2d_transpose(x, w, *, stride=(1, 1), pad=(0, 0), groups=1):
    """Transpose (fractionally-strided) convolution, `miopenTranspose` mode.

    Defined, as in MIOpen, as the data-gradient of the forward convolution
    whose input has the transpose-conv's output shape. Filter layout stays
    KCRS with K = the transpose-conv *input* channels.
    """
    n, c, h, wd = x.shape
    r, s = w.shape[2], w.shape[3]
    ho = (h - 1) * stride[0] - 2 * pad[0] + r
    wo = (wd - 1) * stride[1] - 2 * pad[1] + s
    out_shape = (n, w.shape[1] * groups, ho, wo)
    return conv2d_bwd_data(x, w, out_shape, stride=stride, pad=pad, groups=groups)


def conv_out_shape(x_shape, w_shape, *, stride=(1, 1), pad=(0, 0), dilation=(1, 1)):
    """Output spatial shape formula (shared with the Rust descriptor layer)."""
    n, _, h, w = x_shape
    k, _, r, s = w_shape
    er = (r - 1) * dilation[0] + 1
    es = (s - 1) * dilation[1] + 1
    ho = (h + 2 * pad[0] - er) // stride[0] + 1
    wo = (w + 2 * pad[1] - es) // stride[1] + 1
    return (n, k, ho, wo)


def im2col(x, r, s, *, stride=(1, 1), pad=(0, 0), dilation=(1, 1)):
    """The paper's most-general path: unfold into a (N, C*R*S, Ho*Wo) matrix."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])))
    _, _, hp, wp = xp.shape
    ho = (hp - ((r - 1) * dilation[0] + 1)) // stride[0] + 1
    wo = (wp - ((s - 1) * dilation[1] + 1)) // stride[1] + 1
    cols = []
    for i in range(r):
        for j in range(s):
            di, dj = i * dilation[0], j * dilation[1]
            patch = xp[:, :, di : di + (ho - 1) * stride[0] + 1 : stride[0],
                       dj : dj + (wo - 1) * stride[1] + 1 : stride[1]]
            cols.append(patch.reshape(n, c, ho * wo))
    # stack as (N, C, R*S, Ho*Wo) -> (N, C*R*S, Ho*Wo), C-major to match the
    # (K, C*R*S) filter reshape.
    col = jnp.stack(cols, axis=2).reshape(n, c * r * s, ho * wo)
    return col, (ho, wo)


def conv2d_im2col_gemm(x, w, *, stride=(1, 1), pad=(0, 0), dilation=(1, 1)):
    """im2col + GEMM convolution — the baseline of Figure 6."""
    n = x.shape[0]
    k, c, r, s = w.shape
    col, (ho, wo) = im2col(x, r, s, stride=stride, pad=pad, dilation=dilation)
    wmat = w.reshape(k, c * r * s).astype(jnp.float32)
    out = jnp.einsum("kp,npq->nkq", wmat, col.astype(jnp.float32))
    return out.reshape(n, k, ho, wo).astype(x.dtype)


# ---------------------------------------------------------------------------
# Batch normalization (paper §IV-B)
# ---------------------------------------------------------------------------


def batchnorm_spatial_fwd_train(x, gamma, beta, eps=1e-5):
    """Spatial BN: one (mean, var, gamma, beta) per channel, stats over N,H,W."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=(0, 2, 3), keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=(0, 2, 3), keepdims=True)
    xhat = (xf - mu) / jnp.sqrt(var + eps)
    y = gamma.reshape(1, -1, 1, 1) * xhat + beta.reshape(1, -1, 1, 1)
    return y.astype(x.dtype), mu.reshape(-1), var.reshape(-1)


def batchnorm_spatial_fwd_infer(x, gamma, beta, mean, var, eps=1e-5):
    inv = 1.0 / jnp.sqrt(var.reshape(1, -1, 1, 1) + eps)
    y = gamma.reshape(1, -1, 1, 1) * (x.astype(jnp.float32) - mean.reshape(1, -1, 1, 1)) * inv \
        + beta.reshape(1, -1, 1, 1)
    return y.astype(x.dtype)


def batchnorm_peract_fwd_train(x, gamma, beta, eps=1e-5):
    """Per-activation BN: parameters/statistics per (C,H,W) element, over N."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=0, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=0, keepdims=True)
    xhat = (xf - mu) / jnp.sqrt(var + eps)
    y = gamma[None] * xhat + beta[None]
    return y.astype(x.dtype), mu[0], var[0]


def batchnorm_peract_fwd_infer(x, gamma, beta, mean, var, eps=1e-5):
    y = gamma[None] * (x.astype(jnp.float32) - mean[None]) / jnp.sqrt(var[None] + eps) + beta[None]
    return y.astype(x.dtype)


def batchnorm_spatial_bwd(x, dy, gamma, mu, var, eps=1e-5):
    """Backward pass for spatial BN -> (dx, dgamma, dbeta)."""
    m = x.shape[0] * x.shape[2] * x.shape[3]
    mu_ = mu.reshape(1, -1, 1, 1)
    var_ = var.reshape(1, -1, 1, 1)
    inv = 1.0 / jnp.sqrt(var_ + eps)
    xhat = (x - mu_) * inv
    dgamma = jnp.sum(dy * xhat, axis=(0, 2, 3))
    dbeta = jnp.sum(dy, axis=(0, 2, 3))
    g = gamma.reshape(1, -1, 1, 1)
    dx = (g * inv / m) * (
        m * dy - dbeta.reshape(1, -1, 1, 1) - xhat * dgamma.reshape(1, -1, 1, 1)
    )
    return dx, dgamma, dbeta


def batchnorm_peract_bwd(x, dy, gamma, mu, var, eps=1e-5):
    """Per-activation BN backward -> (dx, dgamma, dbeta); stats over N."""
    n = x.shape[0]
    inv = 1.0 / jnp.sqrt(var[None] + eps)
    xhat = (x - mu[None]) * inv
    dgamma = jnp.sum(dy * xhat, axis=0)
    dbeta = jnp.sum(dy, axis=0)
    dx = (gamma[None] * inv / n) * (
        n * dy - dbeta[None] - xhat * dgamma[None])
    return dx, dgamma, dbeta


# ---------------------------------------------------------------------------
# Activations (§IV-D)
# ---------------------------------------------------------------------------

ACTIVATIONS = {
    "relu": lambda x, alpha=0.0: jnp.maximum(x, 0.0),
    "leaky_relu": lambda x, alpha=0.01: jnp.where(x >= 0, x, alpha * x),
    "tanh": lambda x, alpha=0.0: jnp.tanh(x),
    "sigmoid": lambda x, alpha=0.0: jax.nn.sigmoid(x),
    "elu": lambda x, alpha=1.0: jnp.where(x >= 0, x, alpha * (jnp.exp(x) - 1.0)),
    "clipped_relu": lambda x, alpha=6.0: jnp.clip(x, 0.0, alpha),
    "abs": lambda x, alpha=0.0: jnp.abs(x),
    "identity": lambda x, alpha=0.0: x,
}


def activation_fwd(x, mode, alpha=0.0):
    return ACTIVATIONS[mode](x, alpha)


def activation_bwd(x, dy, mode, alpha=0.0):
    f = lambda t: ACTIVATIONS[mode](t, alpha)
    _, vjp = jax.vjp(f, x)
    return vjp(dy)[0]


# ---------------------------------------------------------------------------
# Pooling (§IV-D)
# ---------------------------------------------------------------------------


def pool2d_fwd(x, *, window=(2, 2), stride=(2, 2), pad=(0, 0), mode="max"):
    init = -jnp.inf if mode == "max" else 0.0
    op = lax.max if mode == "max" else lax.add
    y = lax.reduce_window(
        x,
        jnp.array(init, x.dtype),
        op,
        window_dimensions=(1, 1) + tuple(window),
        window_strides=(1, 1) + tuple(stride),
        padding=((0, 0), (0, 0), (pad[0], pad[0]), (pad[1], pad[1])),
    )
    if mode == "avg":
        y = y / (window[0] * window[1])
    return y


def pool2d_bwd(x, dy, *, window=(2, 2), stride=(2, 2), pad=(0, 0), mode="max"):
    f = lambda t: pool2d_fwd(t, window=window, stride=stride, pad=pad, mode=mode)
    _, vjp = jax.vjp(f, x)
    return vjp(dy)[0]


# ---------------------------------------------------------------------------
# Softmax / LogSoftmax (§IV-D) — over the channel axis, per MIOpen default
# ---------------------------------------------------------------------------


def softmax_fwd(x, *, log=False, axis=1):
    if log:
        return jax.nn.log_softmax(x, axis=axis)
    return jax.nn.softmax(x, axis=axis)


def softmax_bwd(y, dy, *, log=False, axis=1):
    """Backward given the *forward output* y (MIOpen convention)."""
    if log:
        return dy - jnp.exp(y) * jnp.sum(dy, axis=axis, keepdims=True)
    return y * (dy - jnp.sum(dy * y, axis=axis, keepdims=True))


# ---------------------------------------------------------------------------
# Local Response Normalization (§IV-D), cross-channel mode
# ---------------------------------------------------------------------------


def lrn_fwd(x, *, n=5, alpha=1e-4, beta=0.75, k=2.0):
    c = x.shape[1]
    half = n // 2
    sq = x.astype(jnp.float32) ** 2
    padded = jnp.pad(sq, ((0, 0), (half, half), (0, 0), (0, 0)))
    win = sum(padded[:, i : i + c] for i in range(n))
    denom = (k + (alpha / n) * win) ** beta
    return (x / denom).astype(x.dtype)


# ---------------------------------------------------------------------------
# RNN cells (§IV-C): per-timestep references, eqs. (1)-(10)
# ---------------------------------------------------------------------------


def lstm_cell_ref(x_t, h_prev, c_prev, W, R, b=None):
    """One LSTM step. W: (4H, X) rows ordered [i, f, o, c~]; R: (4H, H)."""
    s = x_t @ W.T + h_prev @ R.T
    if b is not None:
        s = s + b
    si, sf, so, sc = jnp.split(s, 4, axis=-1)
    i = jax.nn.sigmoid(si)
    f = jax.nn.sigmoid(sf)
    o = jax.nn.sigmoid(so)
    cbar = jnp.tanh(sc)
    c_t = f * c_prev + i * cbar
    h_t = o * jnp.tanh(c_t)
    return h_t, c_t


def gru_cell_ref(x_t, h_prev, W, R, b=None):
    """One GRU step. W: (3H, X) rows ordered [r, z, n]; R: (3H, H).

    cuDNN/MIOpen variant: n_t = tanh(W_n x + r_t * (R_n h_prev (+ b_n))).
    """
    s_x = x_t @ W.T
    s_h = h_prev @ R.T
    if b is not None:
        bx, bh = b
        s_x = s_x + bx
        s_h = s_h + bh
    xr, xz, xn = jnp.split(s_x, 3, axis=-1)
    hr, hz, hn = jnp.split(s_h, 3, axis=-1)
    r = jax.nn.sigmoid(xr + hr)
    z = jax.nn.sigmoid(xz + hz)
    n = jnp.tanh(xn + r * hn)
    return (1.0 - z) * n + z * h_prev


def vanilla_cell_ref(x_t, h_prev, W, R, b=None, act="tanh"):
    s = x_t @ W.T + h_prev @ R.T
    if b is not None:
        s = s + b
    return jnp.tanh(s) if act == "tanh" else jnp.maximum(s, 0.0)


def lstm_seq_ref(xs, h0, c0, W, R, b=None):
    """Reference LSTM over a sequence. xs: (T, B, X) -> hs: (T, B, H)."""

    def step(carry, x_t):
        h, c = carry
        h2, c2 = lstm_cell_ref(x_t, h, c, W, R, b)
        return (h2, c2), h2

    (_, _), hs = lax.scan(step, (h0, c0), xs)
    return hs


def gru_seq_ref(xs, h0, W, R, b=None):
    def step(h, x_t):
        h2 = gru_cell_ref(x_t, h, W, R, b)
        return h2, h2

    _, hs = lax.scan(step, h0, xs)
    return hs


def vanilla_seq_ref(xs, h0, W, R, b=None, act="tanh"):
    def step(h, x_t):
        h2 = vanilla_cell_ref(x_t, h, W, R, b, act)
        return h2, h2

    _, hs = lax.scan(step, h0, xs)
    return hs


# ---------------------------------------------------------------------------
# CTC loss (§IV-D) — log-space forward algorithm
# ---------------------------------------------------------------------------


def ctc_loss_ref(log_probs, labels, input_len, label_len, blank=0):
    """CTC negative log-likelihood for a single sequence.

    log_probs: (T, V) log-softmax outputs; labels: (L,) int sequence
    (no blanks). Standard alpha recursion over the 2L+1 extended sequence.
    Python-loop implementation used as the test oracle (static lengths).
    """
    L = int(label_len)
    ext = []
    for l in labels[:L]:
        ext.extend([blank, int(l)])
    ext.append(blank)
    S = len(ext)
    ext = jnp.array(ext)

    neg_inf = jnp.array(-1e30, jnp.float32)
    alpha = jnp.full((S,), neg_inf)
    alpha = alpha.at[0].set(log_probs[0, ext[0]])
    if S > 1:
        alpha = alpha.at[1].set(log_probs[0, ext[1]])

    for t in range(1, int(input_len)):
        prev = alpha
        new = jnp.full((S,), neg_inf)
        for s in range(S):
            cand = prev[s]
            if s >= 1:
                cand = jnp.logaddexp(cand, prev[s - 1])
            if s >= 2 and int(ext[s]) != blank and int(ext[s]) != int(ext[s - 2]):
                cand = jnp.logaddexp(cand, prev[s - 2])
            new = new.at[s].set(cand + log_probs[t, ext[s]])
        alpha = new

    ll = alpha[S - 1]
    if S > 1:
        ll = jnp.logaddexp(ll, alpha[S - 2])
    return -ll


def ctc_loss_brute(log_probs, labels, input_len, label_len, blank=0):
    """Brute-force CTC by path enumeration (tiny T/V only; test oracle)."""
    import itertools

    T = int(input_len)
    V = log_probs.shape[1]
    target = tuple(int(l) for l in labels[: int(label_len)])
    total = -jnp.inf
    for path in itertools.product(range(V), repeat=T):
        collapsed = []
        prev = None
        for p in path:
            if p != prev:
                collapsed.append(p)
            prev = p
        decoded = tuple(p for p in collapsed if p != blank)
        if decoded == target:
            lp = sum(float(log_probs[t, path[t]]) for t in range(T))
            total = jnp.logaddexp(total, lp)
    return -total


# ---------------------------------------------------------------------------
# Tensor ops (§IV-D): the miopenOpTensor family
# ---------------------------------------------------------------------------


def op_tensor(a, b, alpha1=1.0, alpha2=1.0, beta=0.0, c=None, op="add"):
    """C = op(alpha1*A, alpha2*B) + beta*C with numpy broadcasting on B."""
    fa, fb = alpha1 * a, alpha2 * b
    if op == "add":
        r = fa + fb
    elif op == "mul":
        r = fa * fb
    elif op == "min":
        r = jnp.minimum(fa, fb)
    elif op == "max":
        r = jnp.maximum(fa, fb)
    else:
        raise ValueError(op)
    if beta != 0.0 and c is not None:
        r = r + beta * c
    return r


# ---------------------------------------------------------------------------
# Fusions (§V): references for the fused kernels
# ---------------------------------------------------------------------------


def fused_conv_bias_act_ref(x, w, bias, *, stride=(1, 1), pad=(0, 0),
                            mode="relu", alpha=0.0):
    y = conv2d_fwd(x, w, stride=stride, pad=pad)
    y = y + bias.reshape(1, -1, 1, 1).astype(y.dtype)
    return activation_fwd(y, mode, alpha)


def fused_bn_act_ref(x, gamma, beta, mean, var, *, eps=1e-5, mode="relu",
                     alpha=0.0, spatial=True):
    if spatial:
        y = batchnorm_spatial_fwd_infer(x, gamma, beta, mean, var, eps)
    else:
        y = batchnorm_peract_fwd_infer(x, gamma, beta, mean, var, eps)
    return activation_fwd(y, mode, alpha)


def fused_conv_bias_bn_act_ref(x, w, bias, gamma, beta, mean, var, *,
                               stride=(1, 1), pad=(0, 0), eps=1e-5,
                               mode="relu", alpha=0.0):
    y = conv2d_fwd(x, w, stride=stride, pad=pad) + bias.reshape(1, -1, 1, 1).astype(x.dtype)
    y = batchnorm_spatial_fwd_infer(y, gamma, beta, mean, var, eps)
    return activation_fwd(y, mode, alpha)
