"""Winograd F(2×2, 3×3) convolution (§IV-A, [Lavin & Gray 2015]).

The paper's workhorse for 3×3/stride-1: 2.25× fewer multiplies than direct
at the cost of transform overhead, and (as the paper stresses) *no
workspace* — transforms are fused around the batched GEMM.

Pipeline:
  V = Bᵀ d B      per 4×4 input tile           (data transform, jnp)
  U = G g Gᵀ      per (k, c) filter            (filter transform, jnp)
  M[ξν] = U[ξν] @ V[ξν]   for the 16 positions (batched Pallas GEMM — the
                                                 hot stage, MXU-shaped)
  Y = Aᵀ M A      per tile                      (output transform, jnp)

Applicability (mirrored by the Rust solver): r = s = 3, stride 1,
dilation 1, groups 1.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import gemm

# F(2x2, 3x3) transform matrices (Lavin & Gray).
BT = jnp.array([[1, 0, -1, 0],
                [0, 1, 1, 0],
                [0, -1, 1, 0],
                [0, 1, 0, -1]], jnp.float32)
G = jnp.array([[1, 0, 0],
               [0.5, 0.5, 0.5],
               [0.5, -0.5, 0.5],
               [0, 0, 1]], jnp.float32)
AT = jnp.array([[1, 1, 1, 0],
                [0, 1, -1, -1]], jnp.float32)


def conv2d_winograd(x, w, *, pad=(1, 1), bm=64, bn=1024, interpret=True):
    """x: (N,C,H,W), w: (K,C,3,3), stride 1 -> (N,K,Ho,Wo)."""
    n, c, h, wd = x.shape
    k, cw, r, s = w.shape
    assert (r, s) == (3, 3), "Winograd F(2,3) requires 3x3 filters"
    assert cw == c

    ho = h + 2 * pad[0] - 2
    wo = wd + 2 * pad[1] - 2

    # pad: conv padding + round Ho/Wo up to multiples of the m=2 tile
    th, tw = (ho + 1) // 2, (wo + 1) // 2
    hp_need = 2 * th + 2   # input extent covered by th tiles
    wp_need = 2 * tw + 2
    xp = jnp.pad(x, ((0, 0), (0, 0),
                     (pad[0], hp_need - h - pad[0]),
                     (pad[1], wp_need - wd - pad[1])))

    # Extract overlapping 4x4 tiles with stride 2: (N, C, th, tw, 4, 4).
    # Perf (EXPERIMENTS.md §Perf L2-1): gather by intra-tile offset — 16
    # strided slices — instead of one slice per tile (O(th·tw) HLO ops,
    # which dominated the measured time at 28x28).
    offs = []
    for i in range(4):
        for j in range(4):
            offs.append(xp[:, :, i : i + 2 * (th - 1) + 1 : 2,
                           j : j + 2 * (tw - 1) + 1 : 2])  # (N, C, th, tw)
    tiles = jnp.stack(offs, axis=-1).reshape(n, c, th, tw, 4, 4)

    xf = tiles.astype(jnp.float32)
    # V = BT @ d @ B  -> (N, C, th, tw, 4, 4)
    V = jnp.einsum("ab,nctwbd,ed->nctwae", BT, xf, BT)
    # U = G @ g @ GT  -> (K, C, 4, 4)
    U = jnp.einsum("ab,kcbd,ed->kcae", G, w.astype(jnp.float32), G)

    p = n * th * tw
    # (16, C, P) and (16, K, C)
    Vm = V.transpose(4, 5, 1, 0, 2, 3).reshape(16, c, p)
    Um = U.transpose(2, 3, 0, 1).reshape(16, k, c)

    # Hot stage: 16 independent GEMMs (K×C)·(C×P) on the Pallas substrate.
    Mm = gemm.batched_matmul(Um, Vm, bm=bm, bn=bn, interpret=interpret)

    M = Mm.reshape(4, 4, k, n, th, tw).transpose(3, 2, 4, 5, 0, 1)
    # Y = AT @ M @ A -> (N, K, th, tw, 2, 2)
    Y = jnp.einsum("ab,nktwbd,ed->nktwae", AT, M, AT)
    y = Y.transpose(0, 1, 2, 4, 3, 5).reshape(n, k, 2 * th, 2 * tw)
    return y[:, :, :ho, :wo].astype(x.dtype)


def conv2d_winograd_bwd_data(dy, w, x_shape, *, pad=(1, 1), bm=32, bn=32,
                             interpret=True):
    """BackwardData for a 3×3/stride-1 conv is itself a 3×3/stride-1 conv
    (flipped, channel-swapped filter, complementary padding) — so Winograd
    applies to the backward-data direction too, as in MIOpen."""
    n, c, h, wd = x_shape
    wrot = jnp.flip(w, axis=(2, 3)).transpose(1, 0, 2, 3)  # (C, K, 3, 3)
    dx = conv2d_winograd(dy, wrot, pad=(2 - pad[0], 2 - pad[1]),
                         bm=bm, bn=bn, interpret=interpret)
    return dx[:, :, :h, :wd]


def flops_ratio():
    """Multiplication saving vs direct for F(2x2,3x3): 36 MACs -> 16."""
    return 2.25


def workspace_bytes(x_shape, w_shape, out_hw, itemsize=4):
    """Honest transform-buffer footprint the find step reports (mirrors
    WinogradSolver::workspace_bytes): U (16·K·Cg) once, V (16·Cg·T) and
    M (16·K·T) per image, T = ceil(Ho/2)·ceil(Wo/2) tiles. Cg is the
    per-group channel count from the filter shape (= C/g), matching the
    Rust formula's sig.c / sig.g."""
    del x_shape  # geometry comes from the filter + output extents
    k, cg = w_shape[0], w_shape[1]
    ho, wo = out_hw
    t = ((ho + 1) // 2) * ((wo + 1) // 2)
    return itemsize * 16 * (k * cg + cg * t + k * t)
