"""Batch normalization Pallas kernels (paper §IV-B).

MIOpen ships specific kernels for {training fwd, inference fwd, backward}
× {spatial, per-activation}; we mirror that six-way split. Spatial kernels
grid over channels (one channel's full (N,H,W) slab per step — the
reduction lives in VMEM); per-activation kernels also grid over channels
with per-(H,W)-element parameters.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# -- spatial: stats over (N, H, W), params per channel ----------------------

def _spatial_train_kernel(x_ref, g_ref, b_ref, y_ref, mu_ref, var_ref, *, eps):
    """x_ref: (N,1,H,W); g/b: (1,); y: (N,1,H,W); mu/var: (1,)."""
    x = x_ref[...].astype(jnp.float32)
    m = x.size
    mu = jnp.sum(x) / m
    var = jnp.sum((x - mu) ** 2) / m
    inv = jax.lax.rsqrt(var + eps)
    y = g_ref[0] * (x - mu) * inv + b_ref[0]
    y_ref[...] = y.astype(y_ref.dtype)
    mu_ref[0] = mu
    var_ref[0] = var


def spatial_fwd_train(x, gamma, beta, *, eps=1e-5, interpret=True):
    n, c, h, w = x.shape
    y, mu, var = pl.pallas_call(
        functools.partial(_spatial_train_kernel, eps=eps),
        grid=(c,),
        in_specs=[
            pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, c, h, w), x.dtype),
            jax.ShapeDtypeStruct((c,), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
        ],
        interpret=interpret,
    )(x, gamma, beta)
    return y, mu, var


def _spatial_infer_kernel(x_ref, g_ref, b_ref, m_ref, v_ref, y_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    inv = jax.lax.rsqrt(v_ref[0] + eps)
    y_ref[...] = (g_ref[0] * (x - m_ref[0]) * inv + b_ref[0]).astype(y_ref.dtype)


def spatial_fwd_infer(x, gamma, beta, mean, var, *, eps=1e-5, interpret=True):
    n, c, h, w = x.shape
    vec = lambda: pl.BlockSpec((1,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_spatial_infer_kernel, eps=eps),
        grid=(c,),
        in_specs=[pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
                  vec(), vec(), vec(), vec()],
        out_specs=pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, h, w), x.dtype),
        interpret=interpret,
    )(x, gamma, beta, mean, var)


def _spatial_bwd_kernel(x_ref, dy_ref, g_ref, mu_ref, var_ref,
                        dx_ref, dg_ref, db_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    m = x.size
    inv = jax.lax.rsqrt(var_ref[0] + eps)
    xhat = (x - mu_ref[0]) * inv
    dg = jnp.sum(dy * xhat)
    db = jnp.sum(dy)
    dx = (g_ref[0] * inv / m) * (m * dy - db - xhat * dg)
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dg_ref[0] = dg
    db_ref[0] = db


def spatial_bwd(x, dy, gamma, mu, var, *, eps=1e-5, interpret=True):
    n, c, h, w = x.shape
    vec = lambda: pl.BlockSpec((1,), lambda i: (i,))
    slab = lambda: pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0))
    dx, dg, db = pl.pallas_call(
        functools.partial(_spatial_bwd_kernel, eps=eps),
        grid=(c,),
        in_specs=[slab(), slab(), vec(), vec(), vec()],
        out_specs=[slab(), vec(), vec()],
        out_shape=[
            jax.ShapeDtypeStruct((n, c, h, w), x.dtype),
            jax.ShapeDtypeStruct((c,), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
        ],
        interpret=interpret,
    )(x, dy, gamma, mu, var)
    return dx, dg, db


# -- per-activation: stats over N, params per (C,H,W) -----------------------

def _peract_train_kernel(x_ref, g_ref, b_ref, y_ref, mu_ref, var_ref, *, eps):
    """x_ref: (N,1,H,W); g/b/mu/var: (1,H,W)."""
    x = x_ref[...].astype(jnp.float32)
    n = x.shape[0]
    mu = jnp.sum(x, axis=0) / n               # (1,H,W)
    var = jnp.sum((x - mu[None]) ** 2, axis=0) / n
    inv = jax.lax.rsqrt(var + eps)
    y = g_ref[...] * (x - mu[None]) * inv[None] + b_ref[...]
    y_ref[...] = y.astype(y_ref.dtype)
    mu_ref[...] = mu
    var_ref[...] = var


def peract_fwd_train(x, gamma, beta, *, eps=1e-5, interpret=True):
    """gamma/beta: (C,H,W)."""
    n, c, h, w = x.shape
    plane = lambda: pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))
    y, mu, var = pl.pallas_call(
        functools.partial(_peract_train_kernel, eps=eps),
        grid=(c,),
        in_specs=[pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
                  plane(), plane()],
        out_specs=[pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
                   plane(), plane()],
        out_shape=[
            jax.ShapeDtypeStruct((n, c, h, w), x.dtype),
            jax.ShapeDtypeStruct((c, h, w), jnp.float32),
            jax.ShapeDtypeStruct((c, h, w), jnp.float32),
        ],
        interpret=interpret,
    )(x, gamma, beta)
    return y, mu, var


def _peract_bwd_kernel(x_ref, dy_ref, g_ref, mu_ref, var_ref,
                       dx_ref, dg_ref, db_ref, *, eps):
    """Per-activation backward: reductions over N only, per (C,H,W) elem."""
    x = x_ref[...].astype(jnp.float32)       # (N, 1, H, W)
    dy = dy_ref[...].astype(jnp.float32)
    n = x.shape[0]
    mu = mu_ref[...][None]                   # (1, 1, H, W)
    inv = jax.lax.rsqrt(var_ref[...] + eps)[None]
    xhat = (x - mu) * inv
    dg = jnp.sum(dy * xhat, axis=0)          # (1, H, W)
    db = jnp.sum(dy, axis=0)
    g = g_ref[...][None]
    dx = (g * inv / n) * (n * dy - db[None] - xhat * dg[None])
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dg_ref[...] = dg
    db_ref[...] = db


def peract_bwd(x, dy, gamma, mu, var, *, eps=1e-5, interpret=True):
    """gamma/mu/var: (C,H,W) -> (dx, dgamma, dbeta)."""
    n, c, h, w = x.shape
    plane = lambda: pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))
    slab = lambda: pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0))
    dx, dg, db = pl.pallas_call(
        functools.partial(_peract_bwd_kernel, eps=eps),
        grid=(c,),
        in_specs=[slab(), slab(), plane(), plane(), plane()],
        out_specs=[slab(), plane(), plane()],
        out_shape=[
            jax.ShapeDtypeStruct((n, c, h, w), x.dtype),
            jax.ShapeDtypeStruct((c, h, w), jnp.float32),
            jax.ShapeDtypeStruct((c, h, w), jnp.float32),
        ],
        interpret=interpret,
    )(x, dy, gamma, mu, var)
    return dx, dg, db


def _peract_infer_kernel(x_ref, g_ref, b_ref, m_ref, v_ref, y_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    inv = jax.lax.rsqrt(v_ref[...] + eps)
    y = g_ref[...][None] * (x - m_ref[...][None]) * inv[None] + b_ref[...][None]
    y_ref[...] = y.astype(y_ref.dtype)


def peract_fwd_infer(x, gamma, beta, mean, var, *, eps=1e-5, interpret=True):
    n, c, h, w = x.shape
    plane = lambda: pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_peract_infer_kernel, eps=eps),
        grid=(c,),
        in_specs=[pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
                  plane(), plane(), plane(), plane()],
        out_specs=pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, h, w), x.dtype),
        interpret=interpret,
    )(x, gamma, beta, mean, var)
