"""Tensor-operator Pallas kernels (paper §IV-D #5): the miopenOpTensor
family — C = op(alpha1·A, alpha2·B) + beta·C with B broadcastable, plus the
bias-add specialization used by the fusion benchmarks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

OPS = ("add", "mul", "min", "max")


def _combine(a, b, op):
    if op == "add":
        return a + b
    if op == "mul":
        return a * b
    if op == "min":
        return jnp.minimum(a, b)
    if op == "max":
        return jnp.maximum(a, b)
    raise ValueError(op)


def _full_kernel(a_ref, b_ref, c_ref, o_ref, *, op, alpha1, alpha2, beta):
    a = alpha1 * a_ref[...].astype(jnp.float32)
    b = alpha2 * b_ref[...].astype(jnp.float32)
    r = _combine(a, b, op)
    if beta != 0.0:
        r = r + beta * c_ref[...].astype(jnp.float32)
    o_ref[...] = r.astype(o_ref.dtype)


def op_tensor(a, b, *, op="add", alpha1=1.0, alpha2=1.0, beta=0.0, c=None,
              block=4096, interpret=True):
    """Full-shape variant: A, B, C all the same shape."""
    assert a.shape == b.shape
    cin = c if c is not None else jnp.zeros_like(a)
    flat_a, flat_b, flat_c = a.reshape(-1), b.reshape(-1), cin.reshape(-1)
    n = flat_a.shape[0]
    blk = min(block, n)
    npad = (-n) % blk
    pads = lambda t: jnp.pad(t, (0, npad))
    spec = lambda: pl.BlockSpec((blk,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_full_kernel, op=op, alpha1=alpha1, alpha2=alpha2,
                          beta=beta),
        grid=((n + npad) // blk,),
        in_specs=[spec(), spec(), spec()],
        out_specs=spec(),
        out_shape=jax.ShapeDtypeStruct((n + npad,), a.dtype),
        interpret=interpret,
    )(pads(flat_a), pads(flat_b), pads(flat_c))
    return out[:n].reshape(a.shape)


def _bias_kernel(a_ref, b_ref, o_ref, *, op, alpha1, alpha2):
    a = alpha1 * a_ref[...].astype(jnp.float32)   # (N,1,H,W)
    b = alpha2 * b_ref[0].astype(jnp.float32)     # scalar per channel
    o_ref[...] = _combine(a, b, op).astype(o_ref.dtype)


def op_tensor_bias(a, bias, *, op="add", alpha1=1.0, alpha2=1.0,
                   interpret=True):
    """Broadcast variant: B is a per-channel (C,) vector over NCHW A.

    This is the `conv + bias` building block of Figure 7a's *unfused*
    arm: a separate kernel launch that re-reads the whole activation.
    """
    n, c, h, w = a.shape
    assert bias.shape == (c,)
    return pl.pallas_call(
        functools.partial(_bias_kernel, op=op, alpha1=alpha1, alpha2=alpha2),
        grid=(c,),
        in_specs=[pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
                  pl.BlockSpec((1,), lambda i: (i,))],
        out_specs=pl.BlockSpec((n, 1, h, w), lambda i: (0, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, bias)
