"""L2: JAX compute graphs built from the L1 Pallas kernels.

Two responsibilities:

1. `layers` — differentiable wrappers (`jax.custom_vjp`) that route both
   the forward AND backward pass through the library's own kernels, the
   exact structure MIOpen exposes (Forward / BackwardData / BackwardWeights
   kernels per primitive).

2. `cnn_*` — the end-to-end tiny CNN used by examples/train_cnn.rs and
   serve_inference.rs: conv→BN→ReLU→pool ×2 → GEMM classifier, with a full
   SGD train step lowered into a single AOT artifact.

Python never runs at serving/training time — these functions exist only to
be lowered by aot.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import activations, batchnorm, direct, gemm, pooling, softmax


# ---------------------------------------------------------------------------
# Differentiable layer wrappers (fwd AND bwd on library kernels)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def conv2d(x, w, stride=(1, 1), pad=(1, 1)):
    return direct.conv2d_direct(x, w, stride=stride, pad=pad)


def _conv_fwd(x, w, stride, pad):
    return conv2d(x, w, stride, pad), (x, w)


def _conv_bwd(stride, pad, res, dy):
    x, w = res
    dx = direct.conv2d_direct_bwd_data(dy, w, x.shape, stride=stride, pad=pad)
    dw = direct.conv2d_direct_bwd_weights(dy, x, w.shape, stride=stride,
                                          pad=pad)
    return dx, dw


conv2d.defvjp(_conv_fwd, _conv_bwd)


@jax.custom_vjp
def bn_train(x, gamma, beta):
    y, _, _ = batchnorm.spatial_fwd_train(x, gamma, beta)
    return y


def _bn_fwd(x, gamma, beta):
    y, mu, var = batchnorm.spatial_fwd_train(x, gamma, beta)
    return y, (x, gamma, mu, var)


def _bn_bwd(res, dy):
    x, gamma, mu, var = res
    dx, dg, db = batchnorm.spatial_bwd(x, dy, gamma, mu, var)
    return dx, dg, db


bn_train.defvjp(_bn_fwd, _bn_bwd)


@jax.custom_vjp
def relu(x):
    return activations.activation_fwd(x, "relu")


def _relu_fwd(x):
    return relu(x), (x,)


def _relu_bwd(res, dy):
    (x,) = res
    return (activations.activation_bwd(x, dy, "relu"),)


relu.defvjp(_relu_fwd, _relu_bwd)


@jax.custom_vjp
def maxpool2(x):
    return pooling.pool2d_fwd(x, window=(2, 2), stride=(2, 2), mode="max")


def _mp_fwd(x):
    y = maxpool2(x)
    return y, (x, y)


def _mp_bwd(res, dy):
    x, y = res
    return (pooling.pool2d_bwd(x, y, dy, window=(2, 2), stride=(2, 2),
                               mode="max"),)


maxpool2.defvjp(_mp_fwd, _mp_bwd)


@jax.custom_vjp
def dense(x, w):
    """x: (B, F), w: (F, O) -> (B, O), on the Pallas GEMM."""
    return gemm.matmul(x, w)


def _dense_fwd(x, w):
    return dense(x, w), (x, w)


def _dense_bwd(res, dy):
    x, w = res
    dx = gemm.matmul(dy, w.T)
    dw = gemm.matmul(x.T, dy)
    return dx, dw


dense.defvjp(_dense_fwd, _dense_bwd)


@jax.custom_vjp
def log_softmax_rows(x):
    """x: (B, V) -> log-softmax over V, on the softmax kernel."""
    return softmax.softmax_fwd(x[:, :, None, None], log=True)[:, :, 0, 0]


def _lsm_fwd(x):
    y = log_softmax_rows(x)
    return y, (y,)


def _lsm_bwd(res, dy):
    (y,) = res
    dx = softmax.softmax_bwd(y[:, :, None, None], dy[:, :, None, None],
                             log=True)[:, :, 0, 0]
    return (dx,)


log_softmax_rows.defvjp(_lsm_fwd, _lsm_bwd)


# ---------------------------------------------------------------------------
# Tiny CNN (E2E validation model)
# ---------------------------------------------------------------------------


def cnn_init(cfg, seed=0):
    """He-initialized parameter pytree (pure numpy -> jnp)."""
    import numpy as np

    rng = np.random.default_rng(seed)

    def he(shape, fan_in):
        return jnp.asarray(
            rng.standard_normal(shape) * np.sqrt(2.0 / fan_in), jnp.float32)

    c, c1, c2 = cfg["channels"], cfg["c1"], cfg["c2"]
    feat = c2 * cfg["hidden_hw"] * cfg["hidden_hw"]
    return {
        "w1": he((c1, c, 3, 3), c * 9),
        "g1": jnp.ones((c1,), jnp.float32),
        "b1": jnp.zeros((c1,), jnp.float32),
        "w2": he((c2, c1, 3, 3), c1 * 9),
        "g2": jnp.ones((c2,), jnp.float32),
        "b2": jnp.zeros((c2,), jnp.float32),
        "wd": he((feat, cfg["classes"]), feat),
    }


PARAM_ORDER = ("w1", "g1", "b1", "w2", "g2", "b2", "wd")


def cnn_logits(params, x, train=True):
    """x: (B, C, 16, 16) -> logits (B, classes). All ops on L1 kernels."""
    y = conv2d(x, params["w1"], (1, 1), (1, 1))
    y = bn_train(y, params["g1"], params["b1"]) if train else \
        _bn_infer_free(y, params["g1"], params["b1"])
    y = relu(y)
    y = maxpool2(y)
    y = conv2d(y, params["w2"], (1, 1), (1, 1))
    y = bn_train(y, params["g2"], params["b2"]) if train else \
        _bn_infer_free(y, params["g2"], params["b2"])
    y = relu(y)
    y = maxpool2(y)
    b = y.shape[0]
    return dense(y.reshape(b, -1), params["wd"])


def _bn_infer_free(y, g, b):
    """Inference-mode BN without running stats (batch stats, no grad)."""
    out, _, _ = batchnorm.spatial_fwd_train(y, g, b)
    return out


def cnn_loss(params, x, labels):
    logits = cnn_logits(params, x, train=True)
    lp = log_softmax_rows(logits)
    b = x.shape[0]
    nll = -jnp.mean(lp[jnp.arange(b), labels])
    return nll


def cnn_train_step(params, x, labels, lr):
    """One SGD step; returns (new_params..., loss). AOT'd as cnn_train."""
    loss, grads = jax.value_and_grad(cnn_loss)(params, x, labels)
    new = {k: params[k] - lr * grads[k] for k in params}
    return tuple(new[k] for k in PARAM_ORDER) + (loss,)


def cnn_infer(params, x):
    """Inference logits + predicted class. AOT'd as cnn_infer."""
    logits = cnn_logits(params, x, train=False)
    return logits, jnp.argmax(logits, axis=1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Synthetic corpus (shared with the Rust driver via seed convention)
# ---------------------------------------------------------------------------


def synth_batch(cfg, seed):
    """Deterministic 3-class toy images: class-dependent oriented gratings
    plus noise. Rust regenerates identical batches from the same seed via
    the artifact `cnn_datagen` below (so the corpus itself is part of the
    lowered graph — no Python at train time)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    b, c, s = cfg["batch"], cfg["channels"], cfg["image"]
    labels = rng.integers(0, cfg["classes"], b)
    xs = np.zeros((b, c, s, s), np.float32)
    yy, xx = np.mgrid[0:s, 0:s].astype(np.float32) / s
    for i, lab in enumerate(labels):
        phase = rng.uniform(0, np.pi)
        if lab == 0:
            base = np.sin(2 * np.pi * 2 * xx + phase)
        elif lab == 1:
            base = np.sin(2 * np.pi * 2 * yy + phase)
        else:
            base = np.sin(2 * np.pi * 2 * (xx + yy) + phase)
        xs[i] = base[None] + 0.3 * rng.standard_normal((c, s, s))
    return jnp.asarray(xs), jnp.asarray(labels, jnp.int32)


def cnn_datagen(seed_arr):
    """Batch generator AS AN ARTIFACT: threefry bits -> images + labels.

    seed_arr: (2,) uint32. Returns (x (B,C,S,S) f32, labels (B,) i32).
    Keeps the training loop 100% Python-free: Rust feeds a step counter.
    """
    cfg = _CFG
    b, c, s = cfg["batch"], cfg["channels"], cfg["image"]
    key = jax.random.wrap_key_data(seed_arr.astype(jnp.uint32),
                                   impl="threefry2x32")
    k1, k2, k3 = jax.random.split(key, 3)
    labels = jax.random.randint(k1, (b,), 0, cfg["classes"])
    yy, xx = jnp.mgrid[0:s, 0:s].astype(jnp.float32) / s
    phase = jax.random.uniform(k2, (b, 1, 1), minval=0.0, maxval=jnp.pi)
    g0 = jnp.sin(2 * jnp.pi * 2 * xx[None] + phase)
    g1 = jnp.sin(2 * jnp.pi * 2 * yy[None] + phase)
    g2 = jnp.sin(2 * jnp.pi * 2 * (xx + yy)[None] + phase)
    base = jnp.where((labels == 0)[:, None, None], g0,
                     jnp.where((labels == 1)[:, None, None], g1, g2))
    noise = 0.3 * jax.random.normal(k3, (b, c, s, s))
    x = base[:, None, :, :] + noise
    return x.astype(jnp.float32), labels.astype(jnp.int32)


from . import configs as _configs  # noqa: E402

_CFG = _configs.CNN
