"""Problem-configuration sets — the single source of truth shared by the
AOT generator (aot.py) and the Rust workload layer (via manifest.json).

Figure 6 configs are sampled from the same networks the paper used
(GoogLeNet / Inception v3 / Inception v4); Figure 7 configs follow the
paper's sweeps (output-channel sweep for CBA, image-size sweep for BN+A).

SCALING NOTE (DESIGN.md §Substitutions): the paper ran full-size ImageNet
layers on Radeon Instinct GPUs. Our measured series executes on CPU-PJRT
through interpret-lowered Pallas kernels, so each config is scaled down
(channels /4, batch 4) to keep the find/bench loops tractable. The GCN
perf model is evaluated on the *same* scaled config, so the measured and
modeled series are directly comparable; relative algorithm ordering is
scale-stable because it is driven by FLOP/byte/launch ratios.

Label format matches Figure 6's x-axis:
  filterH-filterW-inChannels-imageH-imageW-outChannels-padH-padW
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ConvConfig:
    n: int          # batch
    c: int          # input channels
    h: int          # image height
    w: int          # image width
    k: int          # output channels
    r: int          # filter height
    s: int          # filter width
    u: int = 1      # stride h
    v: int = 1      # stride w
    p: int = 0      # pad h
    q: int = 0      # pad w
    l: int = 1      # dilation h
    j: int = 1      # dilation w
    g: int = 1      # groups

    @property
    def label(self) -> str:
        """Figure 6 x-axis label."""
        return f"{self.r}-{self.s}-{self.c}-{self.h}-{self.w}-{self.k}-{self.p}-{self.q}"

    def sig_params(self) -> str:
        return (f"n{self.n}c{self.c}h{self.h}w{self.w}k{self.k}"
                f"r{self.r}s{self.s}u{self.u}v{self.v}p{self.p}q{self.q}"
                f"l{self.l}j{self.j}g{self.g}")

    def out_hw(self):
        er = (self.r - 1) * self.l + 1
        es = (self.s - 1) * self.j + 1
        ho = (self.h + 2 * self.p - er) // self.u + 1
        wo = (self.w + 2 * self.q - es) // self.v + 1
        return ho, wo

    def as_dict(self):
        d = {k: getattr(self, k) for k in
             ("n", "c", "h", "w", "k", "r", "s", "u", "v", "p", "q", "l",
              "j", "g")}
        d["label"] = self.label
        return d


# -- Figure 6: convolution configs -------------------------------------------
# 1x1 set: sampled 1x1 layers (GoogLeNet inception branches, Inception v3
# reductions). Scaled: channels/4, N=4, spatial as in the networks' deeper
# stages.

FIG6_1X1 = [
    ConvConfig(4, 16, 28, 28, 16, 1, 1),          # googlenet 3a 1x1 branch
    ConvConfig(4, 48, 28, 28, 16, 1, 1),          # 3b squeeze
    ConvConfig(4, 120, 14, 14, 32, 1, 1),         # 4a squeeze
    ConvConfig(4, 128, 14, 14, 32, 1, 1),         # 4c
    ConvConfig(4, 208, 7, 7, 64, 1, 1),           # 5a
    ConvConfig(4, 32, 28, 28, 64, 1, 1, u=2, v=2),# inception-v3 reduction
    ConvConfig(4, 64, 14, 14, 96, 1, 1),          # v4 branch
    ConvConfig(4, 96, 7, 7, 128, 1, 1),           # v4 deep
]

# non-1x1 set: 3x3 / 5x5 / 7x7 layers (Winograd's home turf plus cases
# where direct/FFT step in).

FIG6_NON1X1 = [
    ConvConfig(4, 16, 28, 28, 32, 3, 3, p=1, q=1),      # googlenet 3a 3x3
    ConvConfig(4, 32, 28, 28, 48, 3, 3, p=1, q=1),      # 3b 3x3
    ConvConfig(4, 28, 14, 14, 52, 3, 3, p=1, q=1),      # 4b 3x3
    ConvConfig(4, 40, 14, 14, 80, 3, 3, p=1, q=1),      # 4e 3x3
    ConvConfig(4, 4, 28, 28, 8, 5, 5, p=2, q=2),        # 3a 5x5
    ConvConfig(4, 8, 14, 14, 16, 5, 5, p=2, q=2),       # 4e 5x5
    ConvConfig(4, 3, 32, 32, 16, 7, 7, u=2, v=2, p=3, q=3),  # stem 7x7/2
    ConvConfig(4, 16, 14, 14, 48, 3, 3, u=2, v=2, p=1, q=1), # v3 reduction
]

# -- Figure 7a: Conv+Bias+Activation fusion ----------------------------------
# The paper sweeps output channels (speedup shrinks as K grows — bias
# vector pressure). Fixed 3x3 s1 conv, varying K.

FIG7A = [
    ConvConfig(4, 16, 14, 14, k, 3, 3, p=1, q=1)
    for k in (4, 8, 16, 32, 64, 96)
] + [
    ConvConfig(4, 16, 28, 28, k, 1, 1)
    for k in (8, 32)
]

# -- Figure 7b: BatchNorm+Activation fusion -----------------------------------
# The paper sweeps (C, H, W): larger images/channels benefit more.
# Entries are (C, H, W) with N fixed at 4.

FIG7B = [
    (4, 7, 7), (8, 7, 7), (16, 14, 14), (8, 28, 28),
    (16, 28, 28), (32, 28, 28), (16, 56, 56), (32, 56, 56),
]

# -- Grouped / depthwise convolutions (paper §IV-A "Types of convolution") -----
# MobileNet-style depthwise (g == C) and AlexNet-style grouped (g == 2).

GROUPED_CONFIGS = [
    ConvConfig(4, 32, 14, 14, 32, 3, 3, p=1, q=1, g=32),   # depthwise
    ConvConfig(4, 16, 14, 14, 32, 3, 3, p=1, q=1, g=2),    # grouped
    ConvConfig(2, 8, 28, 28, 8, 3, 3, u=2, v=2, p=1, q=1, g=8),
]

# int8 inference configs (paper §I: int8 support; i32-exact f32 accum)
INT8_CONFIGS = [
    ConvConfig(4, 16, 14, 14, 32, 3, 3, p=1, q=1),
    ConvConfig(4, 16, 28, 28, 16, 1, 1),
]

# -- Tuning ablation configs ---------------------------------------------------

TUNE_CONFIGS = [
    ConvConfig(4, 16, 28, 28, 32, 3, 3, p=1, q=1),
    ConvConfig(4, 64, 14, 14, 64, 1, 1),
]
DIRECT_BLOCK_K = [4, 8, 16, 32]
# Winograd transform-domain parallelism variants (mirrors
# WinogradSolver::THREAD_GRID in rust/src/solvers/mod.rs).
WINOGRAD_TILE_THREADS = [1, 2, 4]
# Blocked-GEMM MC x NC tile-grid indices (mirrors gemm::TILE_CONFIGS in
# rust/src/runtime/interp/gemm.rs): one `-gt{i}` artifact per entry so
# tune_convolution can race every tile config.
GEMM_TILE_GRID = [0, 1, 2]
# Depthwise channel-block candidates (mirrors
# DepthwiseSolver::BLOCK_GRID in rust/src/solvers/mod.rs); the `-bk`
# suffix reuses the direct solver's block_k perf-db key so the tuning
# grammar stays closed.
DEPTHWISE_BLOCK_GRID = [4, 8, 16, 32]

# -- NHWC (channels-last) exemplar set -----------------------------------------
# One config per filter family: 1x1 (gemm-friendly), 3x3 (winograd-able),
# 5x5 (fft-able). Sig params stay logical NCHW order for every layout;
# only the buffer axis order (and the `-nhwc` sig tail) changes.

NHWC_CONFIGS = [FIG6_1X1[0], FIG6_NON1X1[0], FIG6_NON1X1[4]]

# -- RNN configs ----------------------------------------------------------------


@dataclass(frozen=True)
class RnnConfig:
    cell: str       # lstm | gru | vanilla
    t: int          # sequence length
    b: int          # batch
    x: int          # input size
    hid: int        # hidden size
    act: str = "tanh"   # vanilla only
    bias: bool = False

    def sig_params(self) -> str:
        return f"t{self.t}b{self.b}x{self.x}h{self.hid}"

    def as_dict(self):
        return {"cell": self.cell, "t": self.t, "b": self.b, "x": self.x,
                "hid": self.hid, "act": self.act, "bias": self.bias}


RNN_CONFIGS = [
    RnnConfig("lstm", 16, 8, 32, 32),
    RnnConfig("lstm", 32, 8, 64, 64),
    RnnConfig("gru", 16, 8, 32, 32),
    RnnConfig("vanilla", 16, 8, 32, 32, act="relu"),
]

# ablation: fused vs naive LSTM over sequence lengths
RNN_ABLATION_T = [4, 8, 16, 32]
RNN_ABLATION_BASE = RnnConfig("lstm", 0, 8, 32, 32)  # t filled per point

# -- primitive (non-conv) artifact shapes --------------------------------------

BN_SHAPES = [(4, 16, 14, 14), (4, 32, 28, 28)]
POOL_SHAPES = [((4, 16, 28, 28), (2, 2), (2, 2), (0, 0), "max"),
               ((4, 16, 28, 28), (2, 2), (2, 2), (0, 0), "avg"),
               ((4, 8, 14, 14), (3, 3), (2, 2), (1, 1), "max")]
SOFTMAX_SHAPES = [(4, 10, 1, 1), (4, 16, 14, 14)]
ACT_SHAPES = [(4, 16, 28, 28)]
ACT_MODES = ["relu", "leaky_relu", "tanh", "sigmoid"]
LRN_SHAPES = [(4, 16, 14, 14)]

# -- E2E CNN (examples/train_cnn.rs, serve_inference.rs) -----------------------

CNN = {
    "image": 16,        # 16x16 inputs
    "channels": 3,
    "classes": 3,
    "c1": 8,            # conv1 output channels
    "c2": 16,           # conv2 output channels
    "hidden_hw": 4,     # after two 2x2 pools: 16 -> 8 -> 4
    "batch": 16,
    "lr": 0.05,
}

# dtypes per artifact family (paper: fp32, fp16, bf16, int8).
# bf16 is a first-class execution dtype (2-byte storage end to end, f32
# accumulate, one rounding at the store — docs/NUMERICS.md): exemplar
# configs mirror the full fwd algorithm zoo plus bwd/wrw and per-dtype
# tuned variants; f16 covers a fwd slice of the same surface.
CONV_DTYPES = ["f32"]
CONV_DTYPES_EXTRA = ["bf16"]
CONV_DTYPES_F16 = ["f16"]
# mixed-precision fwd exemplar set (mirrors configs::builtin_artifacts'
# mp_fwd): two 1x1s, two 3x3s (winograd rides), one 5x5 (fft rides),
# and the tuned 1x1's default
MP_FWD_CONFIGS = (FIG6_1X1[:2] + FIG6_NON1X1[:2] + FIG6_NON1X1[4:5]
                  + TUNE_CONFIGS[1:])
# bwd/wrw mixed-precision exemplar (3x3 p1: winograd bwd applies too)
MP_BWD_CONFIG = FIG6_NON1X1[0]
# dtypes whose tuning variants are AOT'd (per-dtype perf-db resolution)
TUNE_DTYPES = ["f32", "bf16"]
