"""L1 correctness: batchnorm, pooling, softmax, activations, LRN,
tensor-ops kernels vs the jnp oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (activations, batchnorm, lrn, pooling, ref,
                             softmax, tensor_ops)
from .conftest import allclose


def mk(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# -- batch normalization -----------------------------------------------------

BN_SHAPES = [(2, 3, 4, 4), (1, 8, 6, 5), (4, 2, 7, 7), (3, 1, 3, 9)]


@pytest.mark.parametrize("shape", BN_SHAPES)
def test_bn_spatial_train(rng, shape):
    x = mk(rng, shape)
    g = mk(rng, (shape[1],))
    b = mk(rng, (shape[1],))
    y, mu, var = batchnorm.spatial_fwd_train(x, g, b)
    yr, mur, varr = ref.batchnorm_spatial_fwd_train(x, g, b)
    allclose(y, yr)
    allclose(mu, mur)
    allclose(var, varr)


@pytest.mark.parametrize("shape", BN_SHAPES)
def test_bn_spatial_infer(rng, shape):
    x = mk(rng, shape)
    c = shape[1]
    g, b, m = mk(rng, (c,)), mk(rng, (c,)), mk(rng, (c,))
    v = jnp.abs(mk(rng, (c,))) + 0.1
    y = batchnorm.spatial_fwd_infer(x, g, b, m, v)
    yr = ref.batchnorm_spatial_fwd_infer(x, g, b, m, v)
    allclose(y, yr)


@pytest.mark.parametrize("shape", BN_SHAPES)
def test_bn_spatial_bwd(rng, shape):
    x = mk(rng, shape)
    dy = mk(rng, shape)
    g = mk(rng, (shape[1],))
    b = mk(rng, (shape[1],))
    _, mu, var = ref.batchnorm_spatial_fwd_train(x, g, b)
    dx, dg, db = batchnorm.spatial_bwd(x, dy, g, mu, var)
    dxr, dgr, dbr = ref.batchnorm_spatial_bwd(x, dy, g, mu, var)
    allclose(dx, dxr, rtol=1e-3, atol=1e-3)
    allclose(dg, dgr, rtol=1e-3, atol=1e-3)
    allclose(db, dbr, rtol=1e-3, atol=1e-3)


def test_bn_spatial_bwd_matches_autodiff(rng):
    """spatial_bwd must equal jax.grad through the reference forward."""
    import jax

    x = mk(rng, (3, 4, 5, 5))
    g = mk(rng, (4,))
    b = mk(rng, (4,))
    dy = mk(rng, (3, 4, 5, 5))

    def f(x, g, b):
        y, _, _ = ref.batchnorm_spatial_fwd_train(x, g, b)
        return jnp.sum(y * dy)

    dxr, dgr, dbr = jax.grad(f, argnums=(0, 1, 2))(x, g, b)
    _, mu, var = ref.batchnorm_spatial_fwd_train(x, g, b)
    dx, dg, db = batchnorm.spatial_bwd(x, dy, g, mu, var)
    allclose(dx, dxr, rtol=1e-3, atol=1e-3)
    allclose(dg, dgr, rtol=1e-3, atol=1e-3)
    allclose(db, dbr, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("shape", BN_SHAPES)
def test_bn_peract_train(rng, shape):
    x = mk(rng, shape)
    chw = shape[1:]
    g, b = mk(rng, chw), mk(rng, chw)
    y, mu, var = batchnorm.peract_fwd_train(x, g, b)
    yr, mur, varr = ref.batchnorm_peract_fwd_train(x, g, b)
    allclose(y, yr)
    allclose(mu, mur)
    allclose(var, varr)


@pytest.mark.parametrize("shape", BN_SHAPES)
def test_bn_peract_infer(rng, shape):
    x = mk(rng, shape)
    chw = shape[1:]
    g, b, m = mk(rng, chw), mk(rng, chw), mk(rng, chw)
    v = jnp.abs(mk(rng, chw)) + 0.1
    y = batchnorm.peract_fwd_infer(x, g, b, m, v)
    yr = ref.batchnorm_peract_fwd_infer(x, g, b, m, v)
    allclose(y, yr)


@pytest.mark.parametrize("shape", BN_SHAPES)
def test_bn_peract_bwd(rng, shape):
    x = mk(rng, shape)
    dy = mk(rng, shape)
    chw = shape[1:]
    g, b = mk(rng, chw), mk(rng, chw)
    _, mu, var = ref.batchnorm_peract_fwd_train(x, g, b)
    dx, dg, db = batchnorm.peract_bwd(x, dy, g, mu, var)
    dxr, dgr, dbr = ref.batchnorm_peract_bwd(x, dy, g, mu, var)
    allclose(dx, dxr, rtol=1e-3, atol=1e-3)
    allclose(dg, dgr, rtol=1e-3, atol=1e-3)
    allclose(db, dbr, rtol=1e-3, atol=1e-3)


def test_bn_peract_bwd_matches_autodiff(rng):
    import jax

    x = mk(rng, (4, 2, 3, 3))
    g, b = mk(rng, (2, 3, 3)), mk(rng, (2, 3, 3))
    dy = mk(rng, (4, 2, 3, 3))

    def f(x, g, b):
        y, _, _ = ref.batchnorm_peract_fwd_train(x, g, b)
        return jnp.sum(y * dy)

    dxr, dgr, dbr = jax.grad(f, argnums=(0, 1, 2))(x, g, b)
    _, mu, var = ref.batchnorm_peract_fwd_train(x, g, b)
    dx, dg, db = batchnorm.peract_bwd(x, dy, g, mu, var)
    allclose(dx, dxr, rtol=1e-3, atol=1e-3)
    allclose(dg, dgr, rtol=1e-3, atol=1e-3)
    allclose(db, dbr, rtol=1e-3, atol=1e-3)


def test_direct_int8_out_dtype(rng):
    from compile.kernels import direct

    x = jnp.asarray(rng.integers(-4, 4, (1, 3, 8, 8)), jnp.int8)
    w = jnp.asarray(rng.integers(-4, 4, (4, 3, 3, 3)), jnp.int8)
    y = direct.conv2d_direct(x, w, pad=(1, 1), block_k=4,
                             out_dtype=jnp.float32)
    yr = ref.conv2d_fwd(x.astype(jnp.float32), w.astype(jnp.float32),
                        pad=(1, 1))
    assert y.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_bn_bf16(rng):
    x = mk(rng, (2, 3, 4, 4), jnp.bfloat16)
    g, b = mk(rng, (3,)), mk(rng, (3,))
    y, _, _ = batchnorm.spatial_fwd_train(x, g, b)
    yr, _, _ = ref.batchnorm_spatial_fwd_train(x, g, b)
    assert y.dtype == jnp.bfloat16
    allclose(y, yr, rtol=0.05, atol=0.05)


# -- pooling ------------------------------------------------------------------

POOL_CASES = [
    ((2, 3, 8, 8), (2, 2), (2, 2), (0, 0)),
    ((1, 2, 9, 9), (3, 3), (2, 2), (0, 0)),
    ((2, 1, 10, 10), (3, 3), (1, 1), (1, 1)),
    ((1, 4, 7, 5), (2, 3), (2, 1), (0, 1)),
]


@pytest.mark.parametrize("mode", ["max", "avg"])
@pytest.mark.parametrize("case", POOL_CASES)
def test_pool_fwd(rng, mode, case):
    shape, win, stride, pad = case
    x = mk(rng, shape)
    got = pooling.pool2d_fwd(x, window=win, stride=stride, pad=pad, mode=mode)
    want = ref.pool2d_fwd(x, window=win, stride=stride, pad=pad, mode=mode)
    allclose(got, want)


@pytest.mark.parametrize("mode", ["max", "avg"])
@pytest.mark.parametrize("case", POOL_CASES)
def test_pool_bwd(rng, mode, case):
    shape, win, stride, pad = case
    # unique values -> no max ties -> equality-scatter matches vjp oracle
    n = int(np.prod(shape))
    x = jnp.asarray(rng.permutation(n).reshape(shape), jnp.float32)
    y = pooling.pool2d_fwd(x, window=win, stride=stride, pad=pad, mode=mode)
    dy = mk(rng, y.shape)
    got = pooling.pool2d_bwd(x, y, dy, window=win, stride=stride, pad=pad,
                             mode=mode)
    want = ref.pool2d_bwd(x, dy, window=win, stride=stride, pad=pad,
                          mode=mode)
    allclose(got, want)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 2), st.integers(1, 3), st.integers(4, 10),
       st.integers(4, 10), st.sampled_from([2, 3]), st.sampled_from([1, 2]),
       st.booleans())
def test_pool_hypothesis(n, c, h, w, win, stride, is_max):
    if h < win or w < win:
        return
    rng = np.random.default_rng(n + c * 7 + h * 31 + w * 101 + win)
    x = mk(rng, (n, c, h, w))
    mode = "max" if is_max else "avg"
    got = pooling.pool2d_fwd(x, window=(win, win), stride=(stride, stride),
                             mode=mode)
    want = ref.pool2d_fwd(x, window=(win, win), stride=(stride, stride),
                          mode=mode)
    allclose(got, want)


# -- softmax ------------------------------------------------------------------

SM_SHAPES = [(2, 5, 3, 3), (1, 10, 1, 1), (3, 4, 2, 5)]


@pytest.mark.parametrize("log", [False, True])
@pytest.mark.parametrize("shape", SM_SHAPES)
def test_softmax_fwd(rng, log, shape):
    x = mk(rng, shape)
    got = softmax.softmax_fwd(x, log=log)
    want = ref.softmax_fwd(x, log=log)
    allclose(got, want)


@pytest.mark.parametrize("log", [False, True])
@pytest.mark.parametrize("shape", SM_SHAPES)
def test_softmax_bwd(rng, log, shape):
    x = mk(rng, shape)
    y = ref.softmax_fwd(x, log=log)
    dy = mk(rng, shape)
    got = softmax.softmax_bwd(y, dy, log=log)
    want = ref.softmax_bwd(y, dy, log=log)
    allclose(got, want)


def test_softmax_rows_sum_to_one(rng):
    x = mk(rng, (2, 7, 3, 3)) * 10
    y = softmax.softmax_fwd(x)
    sums = np.asarray(jnp.sum(y, axis=1))
    np.testing.assert_allclose(sums, np.ones_like(sums), rtol=1e-5)


def test_softmax_stability_large_logits(rng):
    x = mk(rng, (1, 5, 2, 2)) * 1000
    y = softmax.softmax_fwd(x)
    assert np.all(np.isfinite(np.asarray(y)))


# -- activations --------------------------------------------------------------

@pytest.mark.parametrize("mode", activations.MODES)
def test_activation_fwd(rng, mode):
    x = mk(rng, (2, 3, 5, 7))
    alpha = {"leaky_relu": 0.01, "elu": 1.0, "clipped_relu": 6.0}.get(mode, 0.0)
    got = activations.activation_fwd(x, mode, alpha, block=64)
    want = ref.activation_fwd(x, mode, alpha)
    allclose(got, want)


@pytest.mark.parametrize("mode", [m for m in activations.MODES if m != "abs"])
def test_activation_bwd(rng, mode):
    # abs has a kink at 0 where sign() disagrees with vjp; skip exact-0 case
    x = mk(rng, (2, 3, 5, 7)) + 0.01
    dy = mk(rng, (2, 3, 5, 7))
    alpha = {"leaky_relu": 0.01, "elu": 1.0, "clipped_relu": 6.0}.get(mode, 0.0)
    got = activations.activation_bwd(x, dy, mode, alpha, block=64)
    want = ref.activation_bwd(x, dy, mode, alpha)
    allclose(got, want, rtol=1e-3, atol=1e-3)


def test_activation_nondivisible_block(rng):
    x = mk(rng, (1, 1, 3, 11))   # 33 elements, block 8
    got = activations.activation_fwd(x, "relu", block=8)
    allclose(got, ref.activation_fwd(x, "relu"))


# -- LRN ----------------------------------------------------------------------

@pytest.mark.parametrize("shape,n", [((2, 8, 4, 4), 5), ((1, 3, 5, 5), 3),
                                     ((2, 16, 3, 3), 5)])
def test_lrn(rng, shape, n):
    x = mk(rng, shape)
    got = lrn.lrn_fwd(x, n=n)
    want = ref.lrn_fwd(x, n=n)
    allclose(got, want)


# -- tensor ops ----------------------------------------------------------------

@pytest.mark.parametrize("op", tensor_ops.OPS)
def test_op_tensor(rng, op):
    a = mk(rng, (2, 3, 4, 4))
    b = mk(rng, (2, 3, 4, 4))
    c = mk(rng, (2, 3, 4, 4))
    got = tensor_ops.op_tensor(a, b, op=op, alpha1=1.5, alpha2=0.5,
                               beta=0.25, c=c, block=32)
    want = ref.op_tensor(a, b, alpha1=1.5, alpha2=0.5, beta=0.25, c=c, op=op)
    allclose(got, want)


def test_op_tensor_bias(rng):
    a = mk(rng, (2, 5, 4, 4))
    bias = mk(rng, (5,))
    got = tensor_ops.op_tensor_bias(a, bias)
    want = a + bias.reshape(1, -1, 1, 1)
    allclose(got, want)
