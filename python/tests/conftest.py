import sys
from pathlib import Path

import numpy as np
import pytest

# Allow `from compile.kernels import ...` when pytest is run from python/.
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


@pytest.fixture
def rng():
    return np.random.default_rng(12345)


def allclose(a, b, rtol=2e-4, atol=2e-4):
    a = np.asarray(a, dtype=np.float32)
    b = np.asarray(b, dtype=np.float32)
    np.testing.assert_allclose(a, b, rtol=rtol, atol=atol)
