"""L1 correctness: every convolution algorithm vs the pure-jnp oracle.

This is the core correctness signal of the repo (DESIGN.md §6): the same
kernels tested here are AOT-lowered into the artifacts the Rust library
executes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (direct, fft_conv, im2col_gemm, implicit_gemm,
                             ref, winograd)
from .conftest import allclose


def mk(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


CONV_CASES = [
    # (N, C, H, W, K, R, S, stride, pad, dilation)
    (1, 1, 5, 5, 1, 3, 3, (1, 1), (1, 1), (1, 1)),
    (2, 3, 10, 10, 5, 3, 3, (1, 1), (1, 1), (1, 1)),
    (2, 3, 10, 10, 5, 3, 3, (2, 2), (1, 1), (1, 1)),
    (1, 4, 9, 11, 6, 1, 1, (1, 1), (0, 0), (1, 1)),
    (2, 8, 8, 8, 16, 1, 1, (2, 2), (0, 0), (1, 1)),
    (1, 2, 12, 12, 3, 5, 5, (1, 1), (2, 2), (1, 1)),
    (1, 3, 16, 16, 4, 7, 7, (1, 1), (3, 3), (1, 1)),
    (1, 2, 14, 14, 3, 3, 3, (1, 1), (2, 2), (2, 2)),
    (2, 3, 11, 9, 4, 3, 3, (2, 1), (1, 0), (1, 1)),
    (1, 5, 6, 6, 7, 3, 3, (1, 1), (0, 0), (1, 1)),
]


@pytest.mark.parametrize("case", CONV_CASES)
def test_direct_fwd(rng, case):
    n, c, h, w, k, r, s, stride, pad, dil = case
    x = mk(rng, (n, c, h, w))
    wt = mk(rng, (k, c, r, s))
    got = direct.conv2d_direct(x, wt, stride=stride, pad=pad, dilation=dil,
                               block_k=4)
    want = ref.conv2d_fwd(x, wt, stride=stride, pad=pad, dilation=dil)
    allclose(got, want)


@pytest.mark.parametrize("case", CONV_CASES)
def test_direct_bwd_data(rng, case):
    n, c, h, w, k, r, s, stride, pad, dil = case
    out_shape = ref.conv_out_shape((n, c, h, w), (k, c, r, s),
                                   stride=stride, pad=pad, dilation=dil)
    dy = mk(rng, out_shape)
    wt = mk(rng, (k, c, r, s))
    got = direct.conv2d_direct_bwd_data(dy, wt, (n, c, h, w), stride=stride,
                                        pad=pad, dilation=dil, block_k=4)
    want = ref.conv2d_bwd_data(dy, wt, (n, c, h, w), stride=stride, pad=pad,
                               dilation=dil)
    allclose(got, want)


@pytest.mark.parametrize("case", CONV_CASES)
def test_direct_bwd_weights(rng, case):
    n, c, h, w, k, r, s, stride, pad, dil = case
    out_shape = ref.conv_out_shape((n, c, h, w), (k, c, r, s),
                                   stride=stride, pad=pad, dilation=dil)
    dy = mk(rng, out_shape)
    x = mk(rng, (n, c, h, w))
    got = direct.conv2d_direct_bwd_weights(dy, x, (k, c, r, s),
                                           stride=stride, pad=pad,
                                           dilation=dil, block_k=4)
    want = ref.conv2d_bwd_weights(dy, x, (k, c, r, s), stride=stride,
                                  pad=pad, dilation=dil)
    allclose(got, want, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("case", CONV_CASES)
def test_im2col_gemm(rng, case):
    n, c, h, w, k, r, s, stride, pad, dil = case
    x = mk(rng, (n, c, h, w))
    wt = mk(rng, (k, c, r, s))
    got = im2col_gemm.conv2d_im2col(x, wt, stride=stride, pad=pad,
                                    dilation=dil, bm=8, bn=8)
    want = ref.conv2d_fwd(x, wt, stride=stride, pad=pad, dilation=dil)
    allclose(got, want)


@pytest.mark.parametrize("case", CONV_CASES)
def test_implicit_gemm(rng, case):
    n, c, h, w, k, r, s, stride, pad, dil = case
    x = mk(rng, (n, c, h, w))
    wt = mk(rng, (k, c, r, s))
    got = implicit_gemm.conv2d_implicit_gemm(x, wt, stride=stride, pad=pad,
                                             dilation=dil, block_k=4)
    want = ref.conv2d_fwd(x, wt, stride=stride, pad=pad, dilation=dil)
    allclose(got, want)


WINO_CASES = [c for c in CONV_CASES
              if c[5] == 3 and c[6] == 3 and c[7] == (1, 1) and c[9] == (1, 1)]


@pytest.mark.parametrize("case", WINO_CASES)
def test_winograd(rng, case):
    n, c, h, w, k, r, s, stride, pad, dil = case
    x = mk(rng, (n, c, h, w))
    wt = mk(rng, (k, c, r, s))
    got = winograd.conv2d_winograd(x, wt, pad=pad, bm=8, bn=8)
    want = ref.conv2d_fwd(x, wt, stride=stride, pad=pad)
    allclose(got, want, rtol=5e-4, atol=5e-4)


FFT_CASES = [c for c in CONV_CASES if c[9] == (1, 1)]


@pytest.mark.parametrize("case", FFT_CASES)
def test_fft(rng, case):
    n, c, h, w, k, r, s, stride, pad, dil = case
    x = mk(rng, (n, c, h, w))
    wt = mk(rng, (k, c, r, s))
    got = fft_conv.conv2d_fft(x, wt, stride=stride, pad=pad)
    want = ref.conv2d_fwd(x, wt, stride=stride, pad=pad)
    allclose(got, want, rtol=1e-3, atol=1e-3)


def test_grouped_conv(rng):
    x = mk(rng, (2, 6, 8, 8))
    wt = mk(rng, (6, 3, 3, 3))
    got = direct.conv2d_direct(x, wt, pad=(1, 1), groups=2, block_k=4)
    want = ref.conv2d_fwd(x, wt, pad=(1, 1), groups=2)
    allclose(got, want)


def test_depthwise_conv(rng):
    x = mk(rng, (2, 6, 8, 8))
    wt = mk(rng, (6, 1, 3, 3))
    got = direct.conv2d_direct(x, wt, pad=(1, 1), groups=6, block_k=4)
    want = ref.conv2d_fwd(x, wt, pad=(1, 1), groups=6)
    allclose(got, want)


def test_transpose_conv_shape_and_value(rng):
    # transpose conv == bwd-data of the matching forward conv
    x = mk(rng, (1, 4, 5, 5))
    wt = mk(rng, (4, 3, 3, 3))  # K=4 (transpose-input channels), C=3 out
    y = ref.conv2d_transpose(x, wt, stride=(2, 2), pad=(1, 1))
    assert y.shape == (1, 3, 9, 9)
    got = direct.conv2d_direct_bwd_data(x, wt, (1, 3, 9, 9), stride=(2, 2),
                                        pad=(1, 1), block_k=4)
    allclose(got, y)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_direct_low_precision(rng, dtype):
    x = mk(rng, (1, 3, 8, 8), dtype)
    wt = mk(rng, (4, 3, 3, 3), dtype)
    got = direct.conv2d_direct(x, wt, pad=(1, 1), block_k=4)
    want = ref.conv2d_fwd(x, wt, pad=(1, 1))
    assert got.dtype == dtype
    allclose(got, want, rtol=0.05, atol=0.05)


def test_direct_int8_upcast(rng):
    x = jnp.asarray(rng.integers(-4, 4, (1, 3, 8, 8)), jnp.int8)
    wt = jnp.asarray(rng.integers(-4, 4, (4, 3, 3, 3)), jnp.int8)
    got = direct.conv2d_direct(x.astype(jnp.float32), wt.astype(jnp.float32),
                               pad=(1, 1), block_k=4)
    want = ref.conv2d_fwd(x.astype(jnp.float32), wt.astype(jnp.float32),
                          pad=(1, 1))
    allclose(got, want)
    assert np.all(np.asarray(got) == np.round(np.asarray(got)))


# -- hypothesis sweep over the conv parameter space --------------------------

conv_params = st.tuples(
    st.integers(1, 2),            # N
    st.integers(1, 4),            # C
    st.integers(5, 12),           # H
    st.integers(5, 12),           # W
    st.integers(1, 6),            # K
    st.sampled_from([1, 3, 5]),   # R=S
    st.sampled_from([1, 2]),      # stride
    st.sampled_from([0, 1, 2]),   # pad
)


@settings(max_examples=25, deadline=None)
@given(conv_params)
def test_direct_hypothesis(params):
    n, c, h, w, k, r, stride, pad = params
    if h + 2 * pad < r or w + 2 * pad < r:
        return
    rng = np.random.default_rng(hash(params) % 2**32)
    x = mk(rng, (n, c, h, w))
    wt = mk(rng, (k, c, r, r))
    got = direct.conv2d_direct(x, wt, stride=(stride, stride),
                               pad=(pad, pad), block_k=4)
    want = ref.conv2d_fwd(x, wt, stride=(stride, stride), pad=(pad, pad))
    allclose(got, want)


@settings(max_examples=15, deadline=None)
@given(conv_params)
def test_implicit_gemm_hypothesis(params):
    n, c, h, w, k, r, stride, pad = params
    if h + 2 * pad < r or w + 2 * pad < r:
        return
    rng = np.random.default_rng(hash(params) % 2**32)
    x = mk(rng, (n, c, h, w))
    wt = mk(rng, (k, c, r, r))
    got = implicit_gemm.conv2d_implicit_gemm(
        x, wt, stride=(stride, stride), pad=(pad, pad), block_k=4)
    want = ref.conv2d_fwd(x, wt, stride=(stride, stride), pad=(pad, pad))
    allclose(got, want)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2), st.integers(1, 3), st.integers(6, 14),
       st.integers(6, 14), st.integers(1, 5), st.sampled_from([0, 1]))
def test_winograd_hypothesis(n, c, h, w, k, pad):
    rng = np.random.default_rng(n * 1000 + c * 100 + h * 10 + w + k + pad)
    x = mk(rng, (n, c, h, w))
    wt = mk(rng, (k, c, 3, 3))
    got = winograd.conv2d_winograd(x, wt, pad=(pad, pad), bm=8, bn=8)
    want = ref.conv2d_fwd(x, wt, pad=(pad, pad))
    allclose(got, want, rtol=5e-4, atol=5e-4)


def test_out_shape_formula():
    for case in CONV_CASES:
        n, c, h, w, k, r, s, stride, pad, dil = case
        shp = ref.conv_out_shape((n, c, h, w), (k, c, r, s), stride=stride,
                                 pad=pad, dilation=dil)
        rng = np.random.default_rng(0)
        y = ref.conv2d_fwd(mk(rng, (n, c, h, w)), mk(rng, (k, c, r, s)),
                           stride=stride, pad=pad, dilation=dil)
        assert y.shape == shp
