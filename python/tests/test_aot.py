"""AOT layer tests: HLO-text lowering contract + manifest consistency."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs


def test_to_hlo_text_keeps_large_constants():
    # Regression for the constant({...}) elision bug: a 1000-element
    # constant must survive lowering (it parses back as ZEROS otherwise).
    # Use an opaque numpy payload (arange would lower to an iota instead).
    payload = np.linspace(0.0, 999.0, 1000).astype(np.float32)
    payload[500] = 1234.5
    const = jnp.asarray(payload)
    fn = lambda x: (x + const,)
    text = aot.to_hlo_text(jax.jit(fn).lower(
        jax.ShapeDtypeStruct((1000,), jnp.float32)))
    assert "constant({...})" not in text
    assert "1234.5" in text  # an actual payload value


def test_to_hlo_text_returns_tuple_root():
    fn = lambda x: (x + 1.0, x * 2.0)
    text = aot.to_hlo_text(jax.jit(fn).lower(
        jax.ShapeDtypeStruct((2,), jnp.float32)))
    assert "ROOT" in text and "tuple" in text


def test_fwd_algos_applicability():
    # must mirror rust solvers::applicable (checked there against the
    # manifest; here against the spec directly)
    cc33 = configs.ConvConfig(4, 16, 28, 28, 32, 3, 3, p=1, q=1)
    assert aot.fwd_algos(cc33) == ["gemm", "direct", "implicit", "winograd"]
    cc11 = configs.ConvConfig(4, 16, 28, 28, 32, 1, 1)
    assert aot.fwd_algos(cc11) == ["gemm", "direct", "implicit"]
    cc55 = configs.ConvConfig(4, 4, 28, 28, 8, 5, 5, p=2, q=2)
    assert "fft" in aot.fwd_algos(cc55)
    cc33s2 = configs.ConvConfig(4, 16, 14, 14, 48, 3, 3, u=2, v=2, p=1, q=1)
    assert "winograd" not in aot.fwd_algos(cc33s2)
    assert aot.bwd_algos(cc33) == ["gemm", "direct", "winograd"]
    # depthwise proper (g == c) promotes the dedicated solver to the
    # front; winograd/fft stay out (they require g == 1)
    ccdw = configs.ConvConfig(4, 32, 14, 14, 32, 3, 3, p=1, q=1, g=32)
    assert aot.fwd_algos(ccdw) == ["depthwise", "gemm", "direct", "implicit"]
    ccg2 = configs.ConvConfig(4, 16, 14, 14, 32, 3, 3, p=1, q=1, g=2)
    assert "depthwise" not in aot.fwd_algos(ccg2)


def test_conv_sig_format():
    cc = configs.ConvConfig(4, 16, 28, 28, 32, 3, 3, p=1, q=1)
    assert aot.conv_sig("fwd", "direct", cc, "f32") == \
        "conv_fwd-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32"
    assert aot.conv_sig("wrw", "gemm", cc, "bf16", bk=8).endswith("-bf16-bk8")
    assert aot.conv_sig("fwd", "winograd", cc, "f32", wt=4).endswith("-f32-wt4")
    assert aot.conv_sig("fwd", "gemm", cc, "f32", gt=2).endswith("-f32-gt2")
    # NHWC appends the layout segment after the dtype, before any tuning
    # suffix; NCHW emits nothing (legacy sigs stay byte-identical)
    assert aot.conv_sig("fwd", "direct", cc, "f32", layout="nhwc") == \
        "conv_fwd-direct-n4c16h28w28k32r3s3u1v1p1q1l1j1g1-f32-nhwc"
    assert aot.conv_sig("fwd", "direct", cc, "f32", bk=8,
                        layout="nhwc").endswith("-f32-nhwc-bk8")
    assert aot.conv_sig("fwd", "direct", cc, "f32", layout="nchw") == \
        aot.conv_sig("fwd", "direct", cc, "f32")


def test_nhwc_workspace_formulas():
    from compile.kernels import im2col_gemm

    cc = configs.ConvConfig(4, 16, 28, 28, 32, 3, 3, p=1, q=1)
    ho, wo = cc.out_hw()
    crs = cc.c * cc.r * cc.s
    howo = ho * wo
    # NHWC gemm: y(HoWo, K) = col(HoWo, CRS) · w(K, CRS)^T — the column
    # matrix packs as A and the weights as B, so the MR/NR strip
    # padding swaps roles vs NCHW
    pa = -(-howo // im2col_gemm.GEMM_MR) * im2col_gemm.GEMM_MR * crs
    pb = -(-cc.k // im2col_gemm.GEMM_NR) * im2col_gemm.GEMM_NR * crs
    assert aot.conv_workspace("fwd", "gemm", cc, layout="nhwc") == \
        4 * (crs * howo + pa + pb)
    # direct: fwd runs natively over channels-last strides, bwd/wrw pay
    # the transpose-at-boundary staging copies
    assert aot.conv_workspace("fwd", "direct", cc, layout="nhwc") == 0
    assert aot.conv_workspace("bwd", "direct", cc, layout="nhwc") == \
        aot.nhwc_transpose_scratch(cc)
    # winograd/fft add the boundary copies on top of their NCHW scratch
    assert aot.conv_workspace("fwd", "winograd", cc, layout="nhwc") == \
        aot.conv_workspace("fwd", "winograd", cc) \
        + aot.nhwc_transpose_scratch(cc)
    # depthwise is workspace-free in both layouts
    dw = configs.GROUPED_CONFIGS[0]
    assert aot.conv_workspace("fwd", "depthwise", dw) == 0
    assert aot.conv_workspace("fwd", "depthwise", dw, layout="nhwc") == 0


def test_gemm_workspace_is_arena_aware():
    # per-image col matrix + MR/NR strip-padded packing panels; the batch
    # dimension must NOT multiply in (buffers are arena-reused across n)
    from compile.kernels import im2col_gemm

    cc = configs.ConvConfig(4, 16, 28, 28, 32, 3, 3, p=1, q=1)
    ho, wo = cc.out_hw()
    ws = aot.conv_workspace("fwd", "gemm", cc)
    crs = cc.c * cc.r * cc.s
    howo = ho * wo
    pa = -(-cc.k // im2col_gemm.GEMM_MR) * im2col_gemm.GEMM_MR * crs
    pb = -(-howo // im2col_gemm.GEMM_NR) * im2col_gemm.GEMM_NR * crs
    assert ws == 4 * (crs * howo + pa + pb)
    # doubling the batch leaves the arena footprint unchanged
    cc2 = configs.ConvConfig(8, 16, 28, 28, 32, 3, 3, p=1, q=1)
    assert aot.conv_workspace("fwd", "gemm", cc2) == ws


def test_config_labels_match_paper_format():
    cc = configs.ConvConfig(4, 16, 28, 28, 32, 3, 3, p=1, q=1)
    assert cc.label == "3-3-16-28-28-32-1-1"
    assert cc.out_hw() == (28, 28)
    cc2 = configs.ConvConfig(4, 3, 32, 32, 16, 7, 7, u=2, v=2, p=3, q=3)
    assert cc2.out_hw() == (16, 16)


MANIFEST_PATH = os.path.join(os.path.dirname(__file__), "..", "..",
                             "artifacts", "manifest.json")


@pytest.mark.skipif(not os.path.exists(MANIFEST_PATH),
                    reason="run `make artifacts` first")
def test_manifest_consistency():
    with open(MANIFEST_PATH) as f:
        m = json.load(f)
    arts = m["artifacts"]
    assert len(arts) > 200
    sigs = [a["sig"] for a in arts]
    assert len(sigs) == len(set(sigs)), "duplicate signatures"
    art_dir = os.path.dirname(MANIFEST_PATH)
    for a in arts:
        path = os.path.join(art_dir, a["file"])
        assert os.path.exists(path), f"missing {a['file']}"
        assert a["dtype"] in ("f32", "bf16", "f16", "i32", "u32", "i8")
        for t in a["inputs"] + a["outputs"]:
            assert all(d > 0 for d in t["shape"]), a["sig"]
    # every fig6 panel populated
    for panel in ["fig6a", "fig6b", "fig6c", "fig6d", "fig6e", "fig6f"]:
        count = sum(1 for a in arts if panel in a["tags"])
        assert count >= 12, f"{panel}: only {count} artifacts"
    # the rnn ablation has fused+naive for every T
    for t in configs.RNN_ABLATION_T:
        tagged = [a for a in arts if "abl-rnn" in a["tags"]
                  and a["params"].get("t") == t]
        algos = {a["algo"] for a in tagged}
        assert {"lstm_fused", "lstm_naive"} <= algos, (t, algos)


@pytest.mark.skipif(not os.path.exists(MANIFEST_PATH),
                    reason="run `make artifacts` first")
def test_manifest_conv_workspace_matches_solver_accounting():
    # gemm (im2col column matrix), fft (complex spectra) and winograd
    # (U/V/M transform buffers) report honest workspace; direct/implicit
    # run in place. Mirrors solvers::workspace_for on the Rust side.
    with open(MANIFEST_PATH) as f:
        arts = json.load(f)["artifacts"]
    for a in arts:
        if a["primitive"] != "conv":
            continue
        nhwc = "-nhwc" in a["sig"]
        if a["algo"] in ("gemm", "fft", "winograd"):
            assert a["workspace_bytes"] > 0, a["sig"]
        elif a["algo"] == "direct" and nhwc and a["direction"] != "fwd":
            # NHWC bwd/wrw transpose at the boundary: the f32 NCHW
            # staging copies are charged as workspace
            assert a["workspace_bytes"] > 0, a["sig"]
        else:
            assert a["workspace_bytes"] == 0, a["sig"]


def test_emitter_dedupes_and_merges_tags(tmp_path):
    em = aot.Emitter(str(tmp_path))
    fn = lambda x: (x * 2.0,)
    sp = [aot.spec((2, 2))]
    em.emit("dup-sig", fn, sp, primitive="test", tags=("a",))
    em.emit("dup-sig", fn, sp, primitive="test", tags=("b",))
    assert len(em.manifest) == 1
    assert set(em.manifest[0]["tags"]) == {"a", "b"}
