"""L1/L2 correctness: RNN fused-GEMM assemblies, CTC, and fusion kernels."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ctc, fused, ref, rnn_cells
from .conftest import allclose


def mk(rng, shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


# -- RNN ----------------------------------------------------------------------

RNN_DIMS = [(3, 2, 4, 5), (5, 1, 3, 3), (2, 4, 6, 2)]  # (T, B, X, H)


@pytest.mark.parametrize("dims", RNN_DIMS)
def test_lstm_pointwise(rng, dims):
    _, b, _, h = dims
    s = mk(rng, (b, 4 * h))
    c = mk(rng, (b, h))
    ht, ct = rnn_cells.lstm_pointwise(s, c)
    # reference: eqs 5-10 on the pre-activations
    si, sf, so, sc = jnp.split(s, 4, axis=-1)
    import jax
    i, f, o = jax.nn.sigmoid(si), jax.nn.sigmoid(sf), jax.nn.sigmoid(so)
    cr = f * c + i * jnp.tanh(sc)
    hr = o * jnp.tanh(cr)
    allclose(ht, hr)
    allclose(ct, cr)


@pytest.mark.parametrize("dims", RNN_DIMS)
@pytest.mark.parametrize("with_bias", [False, True])
def test_lstm_fused_vs_ref(rng, dims, with_bias):
    t, b, x, h = dims
    xs = mk(rng, (t, b, x), scale=0.5)
    h0, c0 = mk(rng, (b, h)), mk(rng, (b, h))
    W, R = mk(rng, (4 * h, x), scale=0.5), mk(rng, (4 * h, h), scale=0.5)
    bias = mk(rng, (4 * h,)) if with_bias else None
    got = rnn_cells.lstm_seq_fused(xs, h0, c0, W, R, bias)
    want = ref.lstm_seq_ref(xs, h0, c0, W, R, bias)
    allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("dims", RNN_DIMS)
def test_lstm_naive_vs_fused(rng, dims):
    """The ablation baseline must agree with the fused path bit-for-trend."""
    t, b, x, h = dims
    xs = mk(rng, (t, b, x), scale=0.5)
    h0, c0 = mk(rng, (b, h)), mk(rng, (b, h))
    W, R = mk(rng, (4 * h, x), scale=0.5), mk(rng, (4 * h, h), scale=0.5)
    a = rnn_cells.lstm_seq_fused(xs, h0, c0, W, R)
    b_ = rnn_cells.lstm_seq_naive(xs, h0, c0, W, R)
    allclose(a, b_, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("dims", RNN_DIMS)
@pytest.mark.parametrize("with_bias", [False, True])
def test_gru_fused_vs_ref(rng, dims, with_bias):
    t, b, x, h = dims
    xs = mk(rng, (t, b, x), scale=0.5)
    h0 = mk(rng, (b, h))
    W, R = mk(rng, (3 * h, x), scale=0.5), mk(rng, (3 * h, h), scale=0.5)
    bias = (mk(rng, (3 * h,)), mk(rng, (3 * h,))) if with_bias else None
    got = rnn_cells.gru_seq_fused(xs, h0, W, R, bias)
    want = ref.gru_seq_ref(xs, h0, W, R, bias)
    allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("act", ["tanh", "relu"])
@pytest.mark.parametrize("dims", RNN_DIMS)
def test_vanilla_fused_vs_ref(rng, act, dims):
    t, b, x, h = dims
    xs = mk(rng, (t, b, x), scale=0.5)
    h0 = mk(rng, (b, h))
    W, R = mk(rng, (h, x), scale=0.5), mk(rng, (h, h), scale=0.5)
    got = rnn_cells.vanilla_seq_fused(xs, h0, W, R, act=act)
    want = ref.vanilla_seq_ref(xs, h0, W, R, act=act)
    allclose(got, want, rtol=1e-3, atol=1e-3)


def test_bidirectional_lstm(rng):
    t, b, x, h = 4, 2, 3, 5
    xs = mk(rng, (t, b, x), scale=0.5)
    h0, c0 = mk(rng, (b, h)), mk(rng, (b, h))
    W, R = mk(rng, (4 * h, x), scale=0.5), mk(rng, (4 * h, h), scale=0.5)
    y = rnn_cells.bidirectional(rnn_cells.lstm_seq_fused, xs, h0, c0, W, R)
    assert y.shape == (t, b, 2 * h)
    fwd = ref.lstm_seq_ref(xs, h0, c0, W, R)
    bwd = ref.lstm_seq_ref(jnp.flip(xs, 0), h0, c0, W, R)
    allclose(y[..., :h], fwd, rtol=1e-3, atol=1e-3)
    allclose(y[..., h:], jnp.flip(bwd, 0), rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(1, 3), st.integers(1, 4),
       st.integers(1, 4))
def test_lstm_hypothesis(t, b, x, h):
    rng = np.random.default_rng(t * 997 + b * 101 + x * 13 + h)
    xs = mk(rng, (t, b, x), scale=0.5)
    h0, c0 = mk(rng, (b, h)), mk(rng, (b, h))
    W, R = mk(rng, (4 * h, x), scale=0.5), mk(rng, (4 * h, h), scale=0.5)
    got = rnn_cells.lstm_seq_fused(xs, h0, c0, W, R)
    want = ref.lstm_seq_ref(xs, h0, c0, W, R)
    allclose(got, want, rtol=1e-3, atol=1e-3)


# -- CTC ------------------------------------------------------------------------

def _log_probs(rng, t, v):
    x = rng.standard_normal((t, v)).astype(np.float32)
    x = x - np.log(np.sum(np.exp(x), axis=1, keepdims=True))
    return jnp.asarray(x)


@pytest.mark.parametrize("t,v,label", [(3, 3, [1]), (4, 3, [1, 2]),
                                       (5, 4, [2, 2]), (4, 2, [1, 1]),
                                       (5, 3, [1, 2, 1])])
def test_ctc_vs_brute(rng, t, v, label):
    lp = _log_probs(rng, t, v)
    want = ref.ctc_loss_brute(lp, jnp.array(label), t, len(label))
    got_ref = ref.ctc_loss_ref(lp, jnp.array(label), t, len(label))
    batched = ctc.ctc_loss(lp[None], jnp.array([label + [0] * (4 - len(label))])[:, :4],
                           jnp.array([t]), jnp.array([len(label)]))
    allclose(got_ref, want, rtol=1e-3, atol=1e-3)
    allclose(batched[0], want, rtol=1e-3, atol=1e-3)


def test_ctc_batch_mixed_lengths(rng):
    t, v = 6, 4
    lp = jnp.stack([_log_probs(rng, t, v) for _ in range(3)])
    labels = jnp.array([[1, 2, 0], [3, 0, 0], [1, 1, 2]])
    input_lens = jnp.array([6, 4, 5])
    label_lens = jnp.array([2, 1, 3])
    got = ctc.ctc_loss(lp, labels, input_lens, label_lens)
    for i in range(3):
        want = ref.ctc_loss_ref(lp[i], labels[i], int(input_lens[i]),
                                int(label_lens[i]))
        allclose(got[i], want, rtol=1e-3, atol=1e-3)


def test_ctc_impossible_label_is_inf_like(rng):
    # label longer than input -> probability 0 -> loss very large
    lp = _log_probs(rng, 2, 3)
    got = ctc.ctc_loss(lp[None], jnp.array([[1, 2, 1]]), jnp.array([2]),
                       jnp.array([3]))
    assert float(got[0]) > 1e20


# -- fusion kernels --------------------------------------------------------------

CBA_CASES = [
    # (N, C, H, W, K, R, stride, pad, mode)
    (1, 3, 8, 8, 4, 3, (1, 1), (1, 1), "relu"),
    (2, 4, 10, 10, 8, 1, (1, 1), (0, 0), "leaky_relu"),
    (1, 2, 12, 12, 4, 5, (2, 2), (2, 2), "tanh"),
    (2, 3, 9, 9, 5, 3, (2, 2), (1, 1), "sigmoid"),
]


@pytest.mark.parametrize("case", CBA_CASES)
def test_fused_cba(rng, case):
    n, c, h, w, k, r, stride, pad, mode = case
    x = mk(rng, (n, c, h, w))
    wt = mk(rng, (k, c, r, r))
    bias = mk(rng, (k,))
    alpha = 0.01 if mode == "leaky_relu" else 0.0
    got = fused.conv_bias_act(x, wt, bias, stride=stride, pad=pad,
                              mode=mode, alpha=alpha, block_k=4)
    want = ref.fused_conv_bias_act_ref(x, wt, bias, stride=stride, pad=pad,
                                       mode=mode, alpha=alpha)
    allclose(got, want, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("shape", [(2, 4, 6, 6), (1, 8, 3, 3)])
@pytest.mark.parametrize("mode", ["relu", "tanh"])
def test_fused_bn_act(rng, shape, mode):
    x = mk(rng, shape)
    c = shape[1]
    g, b, m = mk(rng, (c,)), mk(rng, (c,)), mk(rng, (c,))
    v = jnp.abs(mk(rng, (c,))) + 0.1
    got = fused.bn_act(x, g, b, m, v, mode=mode)
    want = ref.fused_bn_act_ref(x, g, b, m, v, mode=mode)
    allclose(got, want)


@pytest.mark.parametrize("case", CBA_CASES[:2])
def test_fused_cbna(rng, case):
    n, c, h, w, k, r, stride, pad, mode = case
    x = mk(rng, (n, c, h, w))
    wt = mk(rng, (k, c, r, r))
    bias = mk(rng, (k,))
    g, b, m = mk(rng, (k,)), mk(rng, (k,)), mk(rng, (k,))
    v = jnp.abs(mk(rng, (k,))) + 0.1
    got = fused.conv_bias_bn_act(x, wt, bias, g, b, m, v, stride=stride,
                                 pad=pad, mode="relu", block_k=4)
    want = ref.fused_conv_bias_bn_act_ref(x, wt, bias, g, b, m, v,
                                          stride=stride, pad=pad, mode="relu")
    allclose(got, want, rtol=5e-4, atol=5e-4)


def test_fused_cba_equals_separate_pipeline(rng):
    """Fused result == conv kernel, then bias kernel, then act kernel."""
    from compile.kernels import activations, direct, tensor_ops

    x = mk(rng, (1, 3, 8, 8))
    wt = mk(rng, (4, 3, 3, 3))
    bias = mk(rng, (4,))
    y1 = direct.conv2d_direct(x, wt, pad=(1, 1), block_k=4)
    y2 = tensor_ops.op_tensor_bias(y1, bias)
    y3 = activations.activation_fwd(y2, "relu")
    got = fused.conv_bias_act(x, wt, bias, pad=(1, 1), mode="relu", block_k=4)
    allclose(got, y3, rtol=5e-4, atol=5e-4)
