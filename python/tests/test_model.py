"""L2 correctness: the differentiable layer wrappers route fwd AND bwd
through library kernels and must agree with plain-JAX autodiff; the CNN
train step must learn."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.kernels import ref
from .conftest import allclose


def mk(rng, shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


def test_conv2d_custom_vjp_matches_autodiff(rng):
    x = mk(rng, (2, 3, 10, 10))
    w = mk(rng, (4, 3, 3, 3))
    dy = mk(rng, (2, 4, 10, 10))

    def lib(x, w):
        return jnp.sum(model.conv2d(x, w, (1, 1), (1, 1)) * dy)

    def plain(x, w):
        return jnp.sum(ref.conv2d_fwd(x, w, stride=(1, 1), pad=(1, 1)) * dy)

    gx1, gw1 = jax.grad(lib, argnums=(0, 1))(x, w)
    gx2, gw2 = jax.grad(plain, argnums=(0, 1))(x, w)
    allclose(gx1, gx2, rtol=1e-3, atol=1e-3)
    allclose(gw1, gw2, rtol=1e-3, atol=1e-3)


def test_bn_train_custom_vjp_matches_autodiff(rng):
    x = mk(rng, (4, 3, 6, 6))
    g = mk(rng, (3,))
    b = mk(rng, (3,))
    dy = mk(rng, (4, 3, 6, 6))

    def lib(x, g, b):
        return jnp.sum(model.bn_train(x, g, b) * dy)

    def plain(x, g, b):
        y, _, _ = ref.batchnorm_spatial_fwd_train(x, g, b)
        return jnp.sum(y * dy)

    for i in range(3):
        gl = jax.grad(lib, argnums=i)(x, g, b)
        gp = jax.grad(plain, argnums=i)(x, g, b)
        allclose(gl, gp, rtol=2e-3, atol=2e-3)


def test_maxpool_and_relu_vjp(rng):
    # unique values avoid max ties
    x = jnp.asarray(rng.permutation(2 * 3 * 8 * 8).reshape(2, 3, 8, 8),
                    jnp.float32) / 10.0
    dy = mk(rng, (2, 3, 4, 4))

    def lib(x):
        return jnp.sum(model.maxpool2(model.relu(x - 5.0)) * dy)

    def plain(x):
        return jnp.sum(ref.pool2d_fwd(jnp.maximum(x - 5.0, 0.0)) * dy)

    allclose(jax.grad(lib)(x), jax.grad(plain)(x), rtol=1e-3, atol=1e-3)


def test_dense_and_logsoftmax_vjp(rng):
    x = mk(rng, (4, 6))
    w = mk(rng, (6, 3))
    labels = jnp.array([0, 2, 1, 0])

    def lib(x, w):
        lp = model.log_softmax_rows(model.dense(x, w))
        return -jnp.mean(lp[jnp.arange(4), labels])

    def plain(x, w):
        lp = jax.nn.log_softmax(x @ w, axis=1)
        return -jnp.mean(lp[jnp.arange(4), labels])

    for i in (0, 1):
        allclose(jax.grad(lib, argnums=i)(x, w),
                 jax.grad(plain, argnums=i)(x, w), rtol=1e-3, atol=1e-3)


def test_train_step_reduces_loss():
    cfg = configs.CNN
    params = model.cnn_init(cfg, seed=0)
    # jit once: the AOT path compiles this same graph via PJRT
    step_fn = jax.jit(lambda p, x, lab: model.cnn_train_step(
        p, x, lab, cfg["lr"]))
    losses = []
    for step in range(12):
        x, lab = model.synth_batch(cfg, step)
        out = step_fn(params, x, lab)
        params = dict(zip(model.PARAM_ORDER, out[:-1]))
        losses.append(float(out[-1]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) * 0.8, losses


def test_datagen_deterministic_and_labeled():
    seed = jnp.array([7, 9], jnp.uint32)
    x1, l1 = model.cnn_datagen(seed)
    x2, l2 = model.cnn_datagen(seed)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    x3, l3 = model.cnn_datagen(jnp.array([8, 9], jnp.uint32))
    assert not np.array_equal(np.asarray(l1), np.asarray(l3)) or \
        not np.array_equal(np.asarray(x1), np.asarray(x3))
    assert set(np.asarray(l1)) <= {0, 1, 2}
    assert x1.shape == (configs.CNN["batch"], configs.CNN["channels"],
                        configs.CNN["image"], configs.CNN["image"])


def test_infer_outputs_argmax():
    cfg = configs.CNN
    params = model.cnn_init(cfg, seed=1)
    x, _ = model.synth_batch(cfg, 3)
    logits, pred = model.cnn_infer(params, x)
    np.testing.assert_array_equal(np.asarray(pred),
                                  np.argmax(np.asarray(logits), axis=1))


@pytest.mark.parametrize("key", list(configs.CNN.keys()))
def test_cnn_config_complete(key):
    assert configs.CNN[key] is not None
